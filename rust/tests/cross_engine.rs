//! Cross-engine equivalence — the reproduction's strongest correctness
//! statement: the SAME trained weights produce the SAME function through
//! three entirely different execution paths:
//!
//! 1. NNCG-generated C (cc + dlopen)         — the paper's contribution,
//! 2. the naive Rust interpreter             — Eq. 1–6 transcription,
//! 3. the JAX/Pallas-authored HLO via PJRT   — the three-layer AOT bridge.
//!
//! Paths 1↔2 are always checked. Path 3 additionally requires the
//! artifacts built by `make artifacts`; those tests self-skip (with a
//! note) when artifacts are absent so `cargo test` works standalone.

use nncg::cc::CompiledCnn;
use nncg::codegen::CodegenOptions;
use nncg::experiments::{build_engine, default_artifacts_dir, default_weights_dir, default_work_dir, load_model};
use nncg::runtime::{EngineKind, InferenceEngine};
use nncg::tensor::Tensor;
use nncg::util::XorShift64;

fn artifacts_available(model: &str) -> bool {
    default_artifacts_dir().join(format!("{model}.hlo.txt")).exists()
}

fn weights_available(model: &str) -> bool {
    default_weights_dir().join(format!("{model}.nncgw")).exists()
}

/// |a - b| must be tiny relative to f32 conv accumulation error.
const TOL: f32 = 2e-4;

fn check_three_way(model_name: &str, trials: usize) {
    if !weights_available(model_name) || !artifacts_available(model_name) {
        eprintln!("SKIP three-way {model_name}: run `make artifacts` first");
        return;
    }
    let model = load_model(model_name, &default_weights_dir()).unwrap();
    let opts = CodegenOptions::sse3();
    let nncg = build_engine(EngineKind::Nncg, &model, &opts, &default_artifacts_dir(), &default_work_dir()).unwrap();
    let interp = build_engine(EngineKind::Interp, &model, &opts, &default_artifacts_dir(), &default_work_dir()).unwrap();
    let xla = build_engine(EngineKind::Xla, &model, &opts, &default_artifacts_dir(), &default_work_dir()).unwrap();

    let mut rng = XorShift64::new(0xE2E);
    for t in 0..trials {
        let x = Tensor::rand(model.input.dims(), 0.0, 1.0, &mut rng);
        let y_interp = interp.infer(&x).unwrap();
        let y_nncg = nncg.infer(&x).unwrap();
        let y_xla = xla.infer(&x).unwrap();
        let e_cn = y_nncg.max_abs_diff(&y_interp).unwrap();
        let e_xla = y_xla.max_abs_diff(&y_interp).unwrap();
        assert!(e_cn < TOL, "{model_name} trial {t}: C vs interp err {e_cn}");
        assert!(e_xla < TOL, "{model_name} trial {t}: XLA vs interp err {e_xla}");
    }
}

#[test]
fn three_way_equivalence_ball() {
    check_three_way("ball", 5);
}

#[test]
fn three_way_equivalence_pedestrian() {
    check_three_way("pedestrian", 3);
}

#[test]
fn three_way_equivalence_robot() {
    check_three_way("robot", 2);
}

/// Full option-matrix verification on the real paper models (the lib test
/// covers the tiny net; this is the heavyweight version).
#[test]
fn generated_c_matches_interp_on_paper_models_all_isas() {
    use nncg::codegen::{Isa, Unroll};
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for isa in [Isa::Generic, Isa::Sse3] {
            for unroll in [Unroll::None, Unroll::KeepOuter2] {
                let opts = CodegenOptions { isa, unroll, ..Default::default() };
                let err =
                    nncg::cc::verify_against_interp(&model, &opts, default_work_dir(), 2, 7).unwrap();
                assert!(err < TOL, "{name} {}: err {err}", opts.tag());
            }
        }
    }
}

/// Full-unroll on the ball net (the paper's fastest configuration).
#[test]
fn full_unroll_ball_matches_interp() {
    let model = load_model("ball", &default_weights_dir()).unwrap();
    let err = nncg::cc::verify_against_interp(
        &model,
        &CodegenOptions::sse3_full_unroll(),
        default_work_dir(),
        3,
        13,
    )
    .unwrap();
    assert!(err < TOL, "err {err}");
}

/// Robot detector (BN folding + leaky ReLU) through generated C.
#[test]
fn robot_with_batchnorm_matches_interp() {
    let model = load_model("robot", &default_weights_dir()).unwrap();
    let err =
        nncg::cc::verify_against_interp(&model, &CodegenOptions::sse3(), default_work_dir(), 2, 3).unwrap();
    assert!(err < TOL, "err {err}");
}

/// Odd channel counts (c_out ∈ {3, 6, 10}) and strided Same-padded convs
/// through the full (isa × unroll × pad-mode × tile) matrix: generated C
/// must match the interpreter within TOL on every combination, padless
/// output must never reference the `nncg_pad` scratch buffer, and odd
/// channel counts must keep vector intrinsics under SSE (remainder lanes,
/// not a scalar cliff).
///
/// The matrix includes `Isa::Neon` rows: x86 CI cannot *execute* NEON, so
/// those rows assert generated-C structure instead of interpreter parity —
/// `arm_neon.h` header, fused `vfmaq_f32` taps, vector loads, and a scalar
/// remainder tail for the odd channel counts.
#[test]
fn odd_channel_strided_same_parity_across_pad_and_tile_matrix() {
    use nncg::codegen::{Isa, PadMode, TileMode, Unroll};
    use nncg::graph::{Activation, Layer, Model, Padding};
    let model = Model::new("oddmix", &[9, 8, 1])
        .push(Layer::conv2d(3, 3, 3, (2, 2), Padding::Same, Activation::Relu))
        .push(Layer::conv2d(6, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::conv2d(10, 2, 2, (2, 2), Padding::Same, Activation::None))
        .push(Layer::softmax())
        .with_random_weights(2027);
    let work = default_work_dir();
    for isa in [Isa::Generic, Isa::Sse3, Isa::Neon] {
        for unroll in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1, Unroll::Full] {
            for pad_mode in [PadMode::Copy, PadMode::Padless] {
                for tile in [TileMode::Off, TileMode::Auto] {
                    let opts = CodegenOptions { isa, unroll, pad_mode, tile, ..Default::default() };
                    let src = nncg::codegen::generate_c(&model, &opts).unwrap();
                    if pad_mode == PadMode::Padless && unroll != Unroll::None {
                        assert!(
                            !src.contains("nncg_pad"),
                            "{}: padless output must not reference nncg_pad",
                            opts.tag()
                        );
                    }
                    if isa == Isa::Sse3 {
                        assert!(
                            src.contains("_mm_"),
                            "{}: odd channel counts must keep vector intrinsics",
                            opts.tag()
                        );
                    }
                    if isa == Isa::Neon {
                        // Structure-only: interpreter comparison can't run
                        // ARM code on this host.
                        assert!(src.contains("#include <arm_neon.h>"), "{}", opts.tag());
                        assert!(src.contains("vfmaq_f32"), "{}: NEON taps must fuse", opts.tag());
                        assert!(src.contains("vld1q_f32"), "{}", opts.tag());
                        assert!(
                            src.contains("float a ="),
                            "{}: odd channels need a scalar tail",
                            opts.tag()
                        );
                        assert!(!src.contains("_mm"), "{}: x86 leak into NEON output", opts.tag());
                        continue;
                    }
                    let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 11).unwrap();
                    assert!(err < TOL, "{}: err {err}", opts.tag());
                }
            }
        }
    }
}

/// Locate a compiler able to syntax-check NEON C: a real ARM cross-gcc if
/// the image has one, else the host compiler with the checked-in
/// declaration-stub `arm_neon.h` (ci/stubs). Returns None when neither
/// exists (test self-skips).
fn neon_syntax_checker() -> Option<(String, Vec<String>)> {
    let have = |cmd: &str| {
        std::process::Command::new(cmd)
            .arg("--version")
            .output()
            .map(|o| o.status.success())
            .unwrap_or(false)
    };
    if have("aarch64-linux-gnu-gcc") {
        return Some(("aarch64-linux-gnu-gcc".to_string(), vec!["-fsyntax-only".into()]));
    }
    // 32-bit ARM gcc refuses arm_neon.h (and lacks vfmaq_f32) unless NEON
    // + VFPv4 are enabled explicitly.
    if have("arm-linux-gnueabihf-gcc") {
        return Some((
            "arm-linux-gnueabihf-gcc".to_string(),
            vec![
                "-fsyntax-only".into(),
                "-mfpu=neon-vfpv4".into(),
                "-mfloat-abi=hard".into(),
            ],
        ));
    }
    let stub = std::path::Path::new("ci/stubs/arm_neon.h");
    if stub.exists() {
        for cc in ["gcc", "cc", "clang"] {
            if have(cc) {
                return Some((
                    cc.to_string(),
                    vec!["-fsyntax-only".into(), "-isystem".into(), "ci/stubs".into()],
                ));
            }
        }
    }
    None
}

/// NEON-generated C for every paper model must be syntactically valid C —
/// checked with an ARM cross compiler when available, else against the
/// intrinsics declaration stub. Covers both multiply-accumulate flavors
/// (`neon` / `neon-vfpv3`) and the fused row-streaming emission.
#[test]
fn neon_generated_c_for_paper_models_passes_syntax_check() {
    use nncg::codegen::{FuseMode, Isa, TileMode, Unroll};
    let Some((cc, flags)) = neon_syntax_checker() else {
        eprintln!("SKIP neon syntax check: no C compiler and no ci/stubs/arm_neon.h");
        return;
    };
    let dir = std::env::temp_dir().join("nncg-neon-syntax");
    std::fs::create_dir_all(&dir).unwrap();
    for name in nncg::graph::zoo::PAPER_MODELS {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for (isa, unroll, tile, fuse) in [
            (Isa::Neon, Unroll::KeepOuter2, TileMode::Auto, FuseMode::Off),
            (Isa::Neon, Unroll::None, TileMode::Off, FuseMode::Off),
            (Isa::Neon, Unroll::KeepOuter2, TileMode::Fixed2D(2, 4), FuseMode::Off),
            (Isa::Neon, Unroll::KeepOuter2, TileMode::Auto, FuseMode::Auto),
            (Isa::NeonVfpv3, Unroll::KeepOuter2, TileMode::Auto, FuseMode::Off),
            (Isa::NeonVfpv3, Unroll::KeepOuter2, TileMode::Auto, FuseMode::Auto),
        ] {
            let opts = CodegenOptions { isa, unroll, tile, fuse, ..Default::default() };
            let src = nncg::codegen::generate_c(&model, &opts).unwrap();
            let c_path = dir.join(format!("{name}-{}.c", opts.tag()));
            std::fs::write(&c_path, &src).unwrap();
            let out = std::process::Command::new(&cc)
                .args(&flags)
                .arg(&c_path)
                .output()
                .unwrap();
            assert!(
                out.status.success(),
                "{name} {}: {cc} rejected NEON output:\n{}",
                opts.tag(),
                String::from_utf8_lossy(&out.stderr)
            );
        }
    }
}

/// int8 NEON emission must be syntactically valid C for every paper
/// model, in both the widening `vmlal_s16` baseline (`neon`) and the
/// ARMv8.2+dotprod `vdotq_s32` flavor (`neon-dot`) — the latter needs
/// `-march=armv8.2-a+dotprod` on a real aarch64 cross gcc (the ci/stubs
/// declaration header accepts it unconditionally).
#[test]
fn int8_neon_generated_c_passes_syntax_check() {
    use nncg::codegen::{DType, FuseMode, Isa};
    let Some((cc, flags)) = neon_syntax_checker() else {
        eprintln!("SKIP int8 neon syntax check: no C compiler and no ci/stubs/arm_neon.h");
        return;
    };
    let dir = std::env::temp_dir().join("nncg-neon-int8-syntax");
    std::fs::create_dir_all(&dir).unwrap();
    for name in nncg::graph::zoo::PAPER_MODELS {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for (isa, fuse) in [
            (Isa::Neon, FuseMode::Off),
            (Isa::Neon, FuseMode::Auto),
            (Isa::NeonDot, FuseMode::Off),
            (Isa::NeonDot, FuseMode::Auto),
        ] {
            let opts = CodegenOptions { isa, fuse, dtype: DType::Int8, ..Default::default() };
            let src = nncg::codegen::generate_c(&model, &opts).unwrap();
            let c_path = dir.join(format!("{name}-{}.c", opts.tag()));
            std::fs::write(&c_path, &src).unwrap();
            let mut cmd = std::process::Command::new(&cc);
            cmd.args(&flags);
            if isa == Isa::NeonDot && cc == "aarch64-linux-gnu-gcc" {
                cmd.arg("-march=armv8.2-a+dotprod");
            }
            let out = cmd.arg(&c_path).output().unwrap();
            assert!(
                out.status.success(),
                "{name} {}: {cc} rejected int8 NEON output:\n{}",
                opts.tag(),
                String::from_utf8_lossy(&out.stderr)
            );
        }
    }
}

fn have_cmd(cmd: &str) -> bool {
    std::process::Command::new(cmd)
        .arg("--version")
        .output()
        .map(|o| o.status.success())
        .unwrap_or(false)
}

/// Deterministic xorshift input identical to the generated harness's
/// (`codegen/harness.rs` keeps the same constants).
fn harness_input(n: usize) -> Vec<f32> {
    let mut s: u64 = 88172645463325252;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        v.push(((s >> 24) & 1023) as f32 / 1023.0);
    }
    v
}

/// NEON *execution* parity (closes PR 2's generate-only gap): generate
/// `--isa neon --harness` C, cross-compile it statically for AArch64, run
/// it under qemu-user, and compare the printed outputs against the
/// interpreter on the harness's deterministic input — fused and unfused,
/// which must also agree bit-for-bit with each other. Self-skips with a
/// notice when qemu-user or the cross compiler is unavailable.
#[test]
fn neon_execution_parity_via_qemu() {
    use nncg::codegen::{FuseMode, Isa};
    let qemu = match ["qemu-aarch64", "qemu-aarch64-static"].iter().find(|q| have_cmd(q)) {
        Some(q) => *q,
        None => {
            eprintln!("SKIP neon execution parity: no qemu-user (qemu-aarch64) on PATH");
            return;
        }
    };
    if !have_cmd("aarch64-linux-gnu-gcc") {
        eprintln!("SKIP neon execution parity: no aarch64-linux-gnu-gcc on PATH");
        return;
    }
    let dir = std::env::temp_dir().join("nncg-neon-qemu");
    std::fs::create_dir_all(&dir).unwrap();
    for name in ["tiny", "ball"] {
        let model = nncg::graph::zoo::by_name(name).unwrap().with_random_weights(4242);
        let x = Tensor::from_vec(model.input.dims(), harness_input(model.input.numel())).unwrap();
        let y_ref = nncg::interp::run(&model, &x).unwrap();
        let mut runs: Vec<Vec<f32>> = Vec::new();
        for fuse in [FuseMode::Off, FuseMode::Auto] {
            let opts =
                CodegenOptions { isa: Isa::Neon, test_harness: true, fuse, ..Default::default() };
            let src = nncg::codegen::generate_c(&model, &opts).unwrap();
            let c_path = dir.join(format!("{name}-{}.c", opts.tag()));
            let exe = dir.join(format!("{name}-{}", opts.tag()));
            std::fs::write(&c_path, &src).unwrap();
            let cc = std::process::Command::new("aarch64-linux-gnu-gcc")
                .args(["-O2", "-static", "-o"])
                .arg(&exe)
                .arg(&c_path)
                .arg("-lm")
                .output()
                .unwrap();
            assert!(
                cc.status.success(),
                "{name} {}: cross-compile failed:\n{}",
                opts.tag(),
                String::from_utf8_lossy(&cc.stderr)
            );
            let run = std::process::Command::new(qemu).arg(&exe).arg("1").output().unwrap();
            assert!(
                run.status.success(),
                "{name} {}: qemu run failed:\n{}",
                opts.tag(),
                String::from_utf8_lossy(&run.stderr)
            );
            let stdout = String::from_utf8_lossy(&run.stdout).to_string();
            let outs: Vec<f32> = stdout
                .lines()
                .filter_map(|l| l.strip_prefix("out["))
                .filter_map(|l| l.split_once("]=").map(|(_, v)| v.trim().parse::<f32>().unwrap()))
                .collect();
            assert_eq!(outs.len(), y_ref.data().len(), "{name} {}: {stdout}", opts.tag());
            for (i, (&a, &b)) in outs.iter().zip(y_ref.data()).enumerate() {
                assert!(
                    (a - b).abs() < TOL,
                    "{name} {} out[{i}]: qemu {a} vs interp {b}",
                    opts.tag()
                );
            }
            runs.push(outs);
        }
        assert_eq!(runs[0], runs[1], "{name}: fused NEON must be bit-identical to unfused");
    }
}

/// Rotated differential under qemu (issue acceptance): ring pointer
/// rotation is verified by *execution* on ARM, not just syntax-checked —
/// unfused, rotated-rolled and phase-expanded-rolled NEON builds of a
/// steadily-rolling chain and of the pedestrian model must print
/// bit-identical outputs under qemu-user and match the interpreter.
/// Self-skips with a notice when the cross toolchain is unavailable.
#[test]
fn neon_rotated_differential_parity_via_qemu() {
    use nncg::codegen::{FuseMode, Isa, RolledMode};
    use nncg::graph::{Activation, Layer, Model, Padding};
    let qemu = match ["qemu-aarch64", "qemu-aarch64-static"].iter().find(|q| have_cmd(q)) {
        Some(q) => *q,
        None => {
            eprintln!("SKIP neon rotated parity: no qemu-user (qemu-aarch64) on PATH");
            return;
        }
    };
    if !have_cmd("aarch64-linux-gnu-gcc") {
        eprintln!("SKIP neon rotated parity: no aarch64-linux-gnu-gcc on PATH");
        return;
    }
    let dir = std::env::temp_dir().join("nncg-neon-qemu-rotated");
    std::fs::create_dir_all(&dir).unwrap();
    let models = [
        // Rolls with a rotated body (3 ring phases) + warm-up ramps.
        Model::new("rollneon", &[24, 10, 3])
            .push(Layer::conv2d(6, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::None))
            .with_random_weights(4243),
        nncg::graph::zoo::by_name("pedestrian").unwrap().with_random_weights(4244),
    ];
    for model in &models {
        let x = Tensor::from_vec(model.input.dims(), harness_input(model.input.numel())).unwrap();
        let y_ref = nncg::interp::run(model, &x).unwrap();
        let mut runs: Vec<Vec<f32>> = Vec::new();
        for (fuse, rolled) in [
            (FuseMode::Off, RolledMode::Auto),
            (FuseMode::Auto, RolledMode::Rotate),
            (FuseMode::Auto, RolledMode::Expand),
        ] {
            let opts = CodegenOptions {
                isa: Isa::Neon,
                test_harness: true,
                fuse,
                fuse_rolled: rolled,
                ..Default::default()
            };
            let src = nncg::codegen::generate_c(model, &opts).unwrap();
            if fuse == FuseMode::Auto && rolled == RolledMode::Rotate {
                assert!(
                    src.contains("rotated ring pointers"),
                    "{}: rotation must fire on ARM output",
                    model.name
                );
            }
            let c_path = dir.join(format!("{}-{}.c", model.name, opts.tag()));
            let exe = dir.join(format!("{}-{}", model.name, opts.tag()));
            std::fs::write(&c_path, &src).unwrap();
            let cc = std::process::Command::new("aarch64-linux-gnu-gcc")
                .args(["-O2", "-static", "-o"])
                .arg(&exe)
                .arg(&c_path)
                .arg("-lm")
                .output()
                .unwrap();
            assert!(
                cc.status.success(),
                "{} {}: cross-compile failed:\n{}",
                model.name,
                opts.tag(),
                String::from_utf8_lossy(&cc.stderr)
            );
            let run = std::process::Command::new(qemu).arg(&exe).arg("1").output().unwrap();
            assert!(
                run.status.success(),
                "{} {}: qemu run failed:\n{}",
                model.name,
                opts.tag(),
                String::from_utf8_lossy(&run.stderr)
            );
            let stdout = String::from_utf8_lossy(&run.stdout).to_string();
            let outs: Vec<f32> = stdout
                .lines()
                .filter_map(|l| l.strip_prefix("out["))
                .filter_map(|l| l.split_once("]=").map(|(_, v)| v.trim().parse::<f32>().unwrap()))
                .collect();
            assert_eq!(outs.len(), y_ref.data().len(), "{} {}: {stdout}", model.name, opts.tag());
            for (i, (&a, &b)) in outs.iter().zip(y_ref.data()).enumerate() {
                assert!(
                    (a - b).abs() < TOL,
                    "{} {} out[{i}]: qemu {a} vs interp {b}",
                    model.name,
                    opts.tag()
                );
            }
            runs.push(outs);
        }
        assert_eq!(runs[0], runs[1], "{}: rotated NEON must be bit-identical to unfused", model.name);
        assert_eq!(runs[0], runs[2], "{}: expanded NEON must be bit-identical to unfused", model.name);
    }
}

/// Row-streaming fusion (the acceptance criterion): fused emission must be
/// **bit-identical** to unfused across the (isa × unroll × tile) matrix —
/// same tap order, same accumulators, only the schedule and buffers change
/// — and still match the interpreter. The custom net covers odd channel
/// counts, a strided Same conv, and a pool inside the fused group.
#[test]
fn fused_rows_bit_identical_to_unfused_across_matrix() {
    use nncg::codegen::{FuseMode, Isa, TileMode, Unroll};
    use nncg::graph::{Activation, Layer, Model, Padding};
    let models = vec![
        load_model("ball", &default_weights_dir()).unwrap(),
        load_model("pedestrian", &default_weights_dir()).unwrap(),
        Model::new("fusemix", &[9, 8, 1])
            .push(Layer::conv2d(6, 3, 3, (2, 2), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .push(Layer::conv2d(10, 3, 3, (1, 1), Padding::Same, Activation::None))
            .push(Layer::leaky_relu(0.1))
            .push(Layer::softmax())
            .with_random_weights(4242),
    ];
    let work = default_work_dir();
    let mut rng = XorShift64::new(0xF05E);
    for model in &models {
        for isa in [Isa::Generic, Isa::Sse3] {
            for unroll in [Unroll::KeepOuter2, Unroll::KeepOuter1] {
                for tile in [TileMode::Off, TileMode::Auto] {
                    let base = CodegenOptions { isa, unroll, tile, ..Default::default() };
                    let fused_opts = CodegenOptions { fuse: FuseMode::Auto, ..base.clone() };
                    let src = nncg::codegen::generate_c(model, &fused_opts).unwrap();
                    // Under KeepOuter1 the statement budget may veto some
                    // groups (cols unroll multiplies the cost); with the
                    // col loop kept every model here must fuse something.
                    if unroll == Unroll::KeepOuter2 {
                        assert!(
                            src.contains("nncg_ring"),
                            "{} {}: expected ring buffers",
                            model.name,
                            fused_opts.tag()
                        );
                    }
                    let unfused = CompiledCnn::build(model, &base, &work).unwrap();
                    let fused = CompiledCnn::from_source(model, &fused_opts, &src, &work).unwrap();
                    for _ in 0..2 {
                        let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
                        let y0 = unfused.infer(&x).unwrap();
                        let y1 = fused.infer(&x).unwrap();
                        assert_eq!(y0, y1, "{} {}: fused output differs", model.name, fused_opts.tag());
                    }
                    let err = nncg::cc::verify_against_interp(model, &fused_opts, &work, 2, 77).unwrap();
                    assert!(err < TOL, "{} {}: err {err}", model.name, fused_opts.tag());
                }
            }
        }
    }
}

/// The full robot detector (the paper's largest model) through fused
/// emission: bit-identical to unfused, matches the interpreter, and the
/// ring buffers measurably shrink the declared static scratch.
#[test]
fn robot_fused_bit_identical_and_scratch_shrinks() {
    use nncg::codegen::{scratch_report, FuseMode};
    let model = load_model("robot", &default_weights_dir()).unwrap();
    let base = CodegenOptions::sse3();
    let fused_opts = CodegenOptions { fuse: FuseMode::Auto, ..base.clone() };
    let unfused_scratch = scratch_report(&model, &base).unwrap();
    let fused_scratch = scratch_report(&model, &fused_opts).unwrap();
    assert!(fused_scratch.ring_count >= 1);
    assert!(
        fused_scratch.total_bytes() < unfused_scratch.total_bytes(),
        "fused {} must beat unfused {}",
        fused_scratch.total_bytes(),
        unfused_scratch.total_bytes()
    );
    let work = default_work_dir();
    let unfused = CompiledCnn::build(&model, &base, &work).unwrap();
    let fused = CompiledCnn::build(&model, &fused_opts, &work).unwrap();
    let mut rng = XorShift64::new(0xB07);
    let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
    let y0 = unfused.infer(&x).unwrap();
    let y1 = fused.infer(&x).unwrap();
    assert_eq!(y0, y1, "robot: fused output must be bit-identical");
    let err = nncg::cc::verify_against_interp(&model, &fused_opts, &work, 1, 3).unwrap();
    assert!(err < TOL, "err {err}");
}

/// Aligned emission (the default) must match the interpreter exactly like
/// the unaligned baseline, and the two must differ only in the intended
/// ways (NNCG_ALIGN attribute + aligned intrinsic forms).
#[test]
fn aligned_emission_matches_interp_and_differs_only_in_alignment() {
    use nncg::codegen::AlignMode;
    let work = default_work_dir();
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for align in [AlignMode::Auto, AlignMode::Off] {
            let opts = CodegenOptions { align, ..CodegenOptions::sse3() };
            let src = nncg::codegen::generate_c(&model, &opts).unwrap();
            assert_eq!(
                src.contains("NNCG_ALIGN"),
                align == AlignMode::Auto,
                "{name} {}",
                opts.tag()
            );
            if align == AlignMode::Off {
                assert!(!src.contains("_mm_load_ps("), "{name}: baseline must stay unaligned");
                assert!(!src.contains("_mm_store_ps("), "{name}: baseline must stay unaligned");
            }
            let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 77).unwrap();
            assert!(err < TOL, "{name} {}: err {err}", opts.tag());
        }
    }
}

/// 2-D register blocks (`--tile 2x4`) through the compiled path: the conv
/// interior walks row pairs and still matches the interpreter.
#[test]
fn tile_2d_matches_interp_on_paper_models() {
    use nncg::codegen::TileMode;
    let work = default_work_dir();
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        let opts = CodegenOptions { tile: TileMode::Fixed2D(2, 4), ..CodegenOptions::sse3() };
        let src = nncg::codegen::generate_c(&model, &opts).unwrap();
        assert!(
            src.contains("i += 2)"),
            "{name}: expected a row-pair interior loop in {}",
            opts.tag()
        );
        let err = nncg::cc::verify_against_interp(&model, &opts, &work, 3, 29).unwrap();
        assert!(err < TOL, "{name} {}: err {err}", opts.tag());
    }
}

/// Paper models through the padless + tiled emission (the new default
/// fast path) against the interpreter.
#[test]
fn paper_models_padless_tiled_match_interp() {
    use nncg::codegen::{PadMode, TileMode};
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        let opts = CodegenOptions {
            pad_mode: PadMode::Padless,
            tile: TileMode::Auto,
            ..CodegenOptions::sse3()
        };
        let src = nncg::codegen::generate_c(&model, &opts).unwrap();
        assert!(!src.contains("nncg_pad"), "{name}: padless output references nncg_pad");
        let err = nncg::cc::verify_against_interp(&model, &opts, default_work_dir(), 2, 21).unwrap();
        assert!(err < TOL, "{name}: err {err}");
    }
}

/// int8 quantization error (tentpole acceptance): the int8 reference
/// path must stay within the **documented** bound of the f32
/// interpreter — 0.12 absolute for the softmax heads (probability
/// space) and 0.12 relative to the output magnitude for the robot
/// detector's logit head. README's `--dtype` section quotes the same
/// numbers; observed error with per-channel conv scales is far lower,
/// the headroom absorbs unlucky calibration draws.
#[test]
fn int8_quant_error_within_documented_bounds() {
    use nncg::interp::{run, run_quantized};
    use nncg::passes::{optimize, quantize_model};
    for (name, bound) in [("ball", 0.12f32), ("pedestrian", 0.12), ("robot", 0.12)] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        let opt = optimize(model).unwrap();
        let qp = quantize_model(&opt).unwrap();
        let mut rng = XorShift64::new(0x1A8);
        let mut worst = 0f32;
        for _ in 0..4 {
            let x = Tensor::rand(opt.input.dims(), -1.0, 1.0, &mut rng);
            let yf = run(&opt, &x).unwrap();
            let yq = run_quantized(&opt, &qp, &x).unwrap();
            // Softmax heads live in [0,1] (mag clamps to 1 → absolute);
            // the robot logit head is bounded relative to its magnitude.
            let mag = yf.data().iter().fold(0f32, |m, v| m.max(v.abs())).max(1.0);
            worst = worst.max(yf.max_abs_diff(&yq).unwrap() / mag);
        }
        assert!(worst < bound, "{name}: int8 error {worst} exceeds documented bound {bound}");
    }
}

/// `--dtype int8` compiled C against the int8 interpreter oracle: the
/// integer chain is identical arithmetic on both sides, so the robot
/// model (no softmax) must match **exactly** and the softmax heads
/// within the float epilogue's libm term.
#[test]
fn int8_generated_c_matches_oracle_exactly() {
    use nncg::codegen::{DType, FuseMode, Isa};
    let work = default_work_dir();
    for name in ["ball", "pedestrian", "robot"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for isa in [Isa::Generic, Isa::Sse3] {
            for fuse in [FuseMode::Off, FuseMode::Auto] {
                let opts = CodegenOptions { isa, fuse, dtype: DType::Int8, ..Default::default() };
                let err =
                    nncg::cc::verify_int8_against_oracle(&model, &opts, &work, 2, 0x18).unwrap();
                assert!(err < 1e-6, "{name} {}: int8 err {err}", opts.tag());
            }
        }
    }
}

/// int8 acceptance: bit-identical output across unfused, fused-rotated
/// and fused-expanded emission. Saturation-free int32 accumulation makes
/// the integer chain order-independent, and the only float code (entry
/// quantize, exit dequantize, softmax epilogue) is byte-identical across
/// the three forms — so the outputs must agree to the last bit, not just
/// within tolerance.
#[test]
fn int8_fused_and_rolled_bit_identical_to_unfused() {
    use nncg::codegen::{DType, FuseMode, RolledMode};
    let work = default_work_dir();
    let mut rng = XorShift64::new(0x18B1);
    for name in ["ball", "pedestrian", "robot"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        let forms = [
            (FuseMode::Off, RolledMode::Auto),
            (FuseMode::Auto, RolledMode::Rotate),
            (FuseMode::Auto, RolledMode::Expand),
        ];
        let cnns: Vec<CompiledCnn> = forms
            .iter()
            .map(|&(fuse, fuse_rolled)| {
                let opts = CodegenOptions {
                    dtype: DType::Int8,
                    fuse,
                    fuse_rolled,
                    ..CodegenOptions::sse3()
                };
                CompiledCnn::build(&model, &opts, &work).unwrap()
            })
            .collect();
        for trial in 0..2 {
            let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
            let y0 = cnns[0].infer(&x).unwrap();
            for (i, cnn) in cnns.iter().enumerate().skip(1) {
                let y = cnn.infer(&x).unwrap();
                assert_eq!(
                    y0, y,
                    "{name} trial {trial}: int8 form {i} must be bit-identical to unfused"
                );
            }
        }
    }
}

/// The dlopen engine must be reusable across threads (coordinator workers).
#[test]
fn compiled_cnn_is_thread_safe() {
    let model = load_model("ball", &default_weights_dir()).unwrap();
    let cnn = std::sync::Arc::new(
        CompiledCnn::build(&model, &CodegenOptions::sse3(), default_work_dir()).unwrap(),
    );
    let mut rng = XorShift64::new(5);
    let x = Tensor::rand(&[16, 16, 1], 0.0, 1.0, &mut rng);
    let expected = cnn.infer(&x).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let cnn = std::sync::Arc::clone(&cnn);
            let x = x.clone();
            let expected = expected.clone();
            std::thread::spawn(move || {
                for _ in 0..50 {
                    let y = cnn.infer(&x).unwrap();
                    assert_eq!(y, expected);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
