//! CI code-size gate for the steady-state rolled fused emission.
//!
//! The rolled form exists so that big planes fuse without the generated C
//! (and its gcc compile time) exploding. This suite pins that property:
//!
//! * robot and pedestrian fuse at full depth — no statement-budget group
//!   splits — and their schedules roll;
//! * rolling shrinks the fused robot C by a guaranteed factor against the
//!   fully unrolled row schedule of the *same* groups (`--fuse-rolled
//!   off`), and by ≥5× in the tall-plane regime the optimization targets;
//! * ring **pointer rotation** shrinks the steady-state loop body itself
//!   by ≥2× against the phase-expanded form on every `phases ≥ 3` group
//!   (the body drops from `pattern × phases` to one pattern period);
//! * the rolled robot still compiles inside a wall-clock budget.

use nncg::codegen::{generate_c, CodegenOptions, FuseMode, RolledMode};
use nncg::graph::{zoo, Activation, Layer, Model, Padding};

fn stmts(src: &str) -> usize {
    src.matches(';').count()
}

fn rolled(base: &CodegenOptions) -> CodegenOptions {
    CodegenOptions { fuse: FuseMode::Auto, fuse_rolled: RolledMode::Auto, ..base.clone() }
}

fn unrolled(base: &CodegenOptions) -> CodegenOptions {
    CodegenOptions { fuse: FuseMode::Auto, fuse_rolled: RolledMode::Off, ..base.clone() }
}

fn with_mode(base: &CodegenOptions, mode: RolledMode) -> CodegenOptions {
    CodegenOptions { fuse: FuseMode::Auto, fuse_rolled: mode, ..base.clone() }
}

/// Statement count of the FIRST steady-state loop body in `src`: seek the
/// steady-state marker, then the `for (i = ...)` that follows, and count
/// `;` until its brace closes.
fn first_body_stmts(src: &str) -> usize {
    let at = src.find("/* steady state:").expect("no steady-state marker");
    let rel = src[at..].find("for (i = 0; i <").expect("no steady-state loop");
    let body = &src[at + rel..];
    let open = body.find('{').unwrap();
    let mut depth = 0usize;
    let mut count = 0usize;
    for ch in body[open..].chars() {
        match ch {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return count;
                }
            }
            ';' => count += 1,
            _ => {}
        }
    }
    panic!("unbalanced steady-state body");
}

/// A streaming chain with the tall planes (96 rows) the ring buffers are
/// for — the regime where the paper models' successors live.
fn tall_stream_net() -> Model {
    Model::new("stream96", &[96, 96, 3])
        .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::Relu))
        .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::Relu))
        .push(Layer::maxpool(2, 2))
        .with_random_weights(7)
}

#[test]
fn robot_fuses_full_depth_and_rolling_shrinks_statement_count() {
    let base = CodegenOptions::sse3();
    let robot = zoo::by_name("robot").unwrap().with_random_weights(5);
    let src_rolled = generate_c(&robot, &rolled(&base)).unwrap();
    // Full-depth fusion: exactly two groups, both rolled, no budget splits.
    assert_eq!(
        src_rolled.matches("/* fused group:").count(),
        2,
        "robot must fuse into exactly two full-depth groups"
    );
    assert!(src_rolled.contains("/* fused group: layers 0..3"));
    assert!(src_rolled.contains("/* fused group: layers 4..6"));
    assert_eq!(
        src_rolled.matches("/* steady state:").count(),
        2,
        "both robot groups must emit steady-state loops"
    );
    assert!(!src_rolled.contains('%'), "rolled emission must not introduce runtime modulo");
    // Same groups, fully unrolled row schedule (the PR 3 emission form at
    // this depth — the thing the statement budget used to protect gcc
    // from). Rolling must cut the statement count decisively. The exact
    // factor is geometry-bound: robot's post-pool planes are only 15–30
    // rows tall, which caps the win near 3× (see the tall-plane test for
    // the ≥5× regime).
    let src_unrolled = generate_c(&robot, &unrolled(&base)).unwrap();
    let (r, u) = (stmts(&src_rolled), stmts(&src_unrolled));
    assert!(
        r * 2 <= u,
        "rolled robot must halve the unrolled fused statement count: rolled={r} unrolled={u}"
    );
    assert!(src_rolled.len() * 2 <= src_unrolled.len(), "byte size must shrink alongside");
}

/// Rotation gate (issue acceptance): on groups with `phases >= 3`, the
/// rotated steady-state body must hold exactly one op-pattern period —
/// at least 2× fewer statements than the phase-expanded body of the SAME
/// group (3× expected at 3 phases; the slack absorbs the rotation block).
#[test]
fn rotation_halves_the_steady_state_body_on_phase3_groups() {
    let base = CodegenOptions::sse3();
    // robot group [0..4): period 5, 3 ring phases (pinned in
    // schedule.rs::rotating_robot_first_group_shape).
    for model in [zoo::by_name("robot").unwrap().with_random_weights(5), tall_stream_net()] {
        let rot = generate_c(&model, &with_mode(&base, RolledMode::Rotate)).unwrap();
        let exp = generate_c(&model, &with_mode(&base, RolledMode::Expand)).unwrap();
        assert!(rot.contains("rotated ring pointers"), "{}: rotation must fire", model.name);
        assert!(exp.contains("frozen ring slots"), "{}: expansion must fire", model.name);
        let (rb, eb) = (first_body_stmts(&rot), first_body_stmts(&exp));
        assert!(
            rb * 2 <= eb,
            "{}: rotated body must be >=2x smaller: rotated={rb} expanded={eb}",
            model.name
        );
        // The whole-file ratio must move the same direction, and the
        // default (auto) must pick the rotated form.
        assert!(stmts(&rot) < stmts(&exp), "{}", model.name);
        let auto = generate_c(&model, &rolled(&base)).unwrap();
        assert_eq!(auto, rot, "{}: auto must emit the rotated form", model.name);
    }
}

#[test]
fn tall_planes_roll_at_least_five_times_smaller() {
    let base = CodegenOptions::sse3();
    let m = tall_stream_net();
    let src_rolled = generate_c(&m, &rolled(&base)).unwrap();
    assert!(src_rolled.contains("/* steady state:"), "stream chain must roll");
    let src_unrolled = generate_c(&m, &unrolled(&base)).unwrap();
    let (r, u) = (stmts(&src_rolled), stmts(&src_unrolled));
    assert!(
        r * 5 <= u,
        "tall-plane rolled emission must be >=5x smaller: rolled={r} unrolled={u}"
    );
}

#[test]
fn pedestrian_fuses_full_depth_and_shrinks() {
    let base = CodegenOptions::sse3();
    let ped = zoo::by_name("pedestrian").unwrap().with_random_weights(5);
    let src_rolled = generate_c(&ped, &rolled(&base)).unwrap();
    assert_eq!(
        src_rolled.matches("/* fused group:").count(),
        2,
        "pedestrian must fuse into exactly two full-depth groups"
    );
    assert!(src_rolled.contains("/* steady state:"), "pedestrian groups must roll");
    let src_unrolled = generate_c(&ped, &unrolled(&base)).unwrap();
    assert!(
        stmts(&src_rolled) < stmts(&src_unrolled),
        "rolling must not grow pedestrian's generated C"
    );
}

/// gcc wall-time budget: the rolled fused robot — the biggest snapshot
/// configuration — must stay comfortably compilable. (Content-cached, so
/// reruns are instant; skipped when no C compiler is present.)
#[test]
fn robot_rolled_compiles_within_wall_time_budget() {
    if nncg::cc::CcDriver::detect().is_err() {
        eprintln!("SKIP compile budget: no C compiler on this host");
        return;
    }
    let robot = zoo::by_name("robot").unwrap().with_random_weights(5);
    let opts = rolled(&CodegenOptions::sse3());
    let work = std::env::temp_dir().join("nncg-code-size-gate");
    let t0 = std::time::Instant::now();
    let cnn = nncg::cc::CompiledCnn::build(&robot, &opts, &work).unwrap();
    let elapsed = t0.elapsed();
    // Generous enough for a slow shared runner compiling the rolled file
    // cold (~2-3 min observed headroom), far below what the unrolled
    // full-depth schedule would need.
    assert!(
        elapsed.as_secs() < 600,
        "rolled robot took {elapsed:?} to build (budget 600s)"
    );
    // And it still runs.
    let mut rng = nncg::util::XorShift64::new(3);
    let x = nncg::tensor::Tensor::rand(robot.input.dims(), 0.0, 1.0, &mut rng);
    cnn.infer(&x).unwrap();
}
