//! Socket chaos suite for the TCP serving front-end.
//!
//! Extends the sharded chaos contract (`chaos_sharded.rs`) across the
//! wire: the exactly-one-reply guarantee must survive client disconnects
//! mid-frame, slow-loris partial frames, injected connection drops and
//! partial writes (`NNCG_FAULTS` net sites), shard kill-storms under
//! pipelined TCP load, and a `stop_with_timeout` shutdown that answers
//! in-flight connections with status `Stopped`. Every scenario is seeded
//! (`NNCG_CHAOS_SEED`; CI runs 1, 2, 3) and gates on the accounting
//! invariant: submitted == replied + shed, lost == 0.

use nncg::coordinator::{
    serve_sharded, NetClient, NetConfig, NetError, NetServer, Router, ServeError, ServerHandle,
    ShardConfig, StealPolicy,
};
use nncg::faults::{FaultPlan, FaultSite, FaultSpec, FaultyEngine};
use nncg::graph::zoo;
use nncg::interp::InterpEngine;
use nncg::runtime::InferenceEngine;
use nncg::tensor::Tensor;
use nncg::util::XorShift64;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn chaos_seed() -> u64 {
    std::env::var("NNCG_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// The three paper models on interpreter engines (deterministic weights),
/// plus one seeded input per model.
fn paper_router(seed: u64) -> (Arc<Router>, Vec<(&'static str, Tensor)>) {
    let router = Arc::new(Router::new());
    let mut inputs = Vec::new();
    let mut rng = XorShift64::new(seed ^ 0xB17);
    for (name, model) in [
        ("ball", zoo::ball_classifier().with_random_weights(11)),
        ("pedestrian", zoo::pedestrian_classifier().with_random_weights(12)),
        ("robot", zoo::robot_detector().with_random_weights(13)),
    ] {
        let dims = model.input.dims().to_vec();
        router.register(name, Arc::new(InterpEngine::new(model).unwrap()));
        inputs.push((name, Tensor::rand(&dims, 0.0, 1.0, &mut rng)));
    }
    (router, inputs)
}

fn tiny_handle(cfg: ShardConfig) -> ServerHandle {
    let router = Arc::new(Router::new());
    router.register(
        "tiny",
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap()),
    );
    serve_sharded(router, cfg)
}

fn tiny_input() -> Tensor {
    Tensor::from_vec(&[8, 8, 1], vec![0.5; 64]).unwrap()
}

/// Acceptance: loopback TCP replies are **bit-identical** to in-process
/// `Submitter` replies for the three paper models.
#[test]
fn tcp_replies_bit_identical_to_in_process_for_paper_models() {
    let (router, inputs) = paper_router(chaos_seed());
    let handle = serve_sharded(
        router,
        ShardConfig { shards: 2, workers_per_shard: 1, ..ShardConfig::default() },
    );
    let server =
        NetServer::start(handle.submitter(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let submitter = handle.submitter();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    for (model, input) in &inputs {
        let local = submitter.infer(model, input.clone()).expect("in-process reply");
        let remote = client.infer(model, input).expect("tcp reply");
        assert_eq!(remote, local, "{model}: TCP reply must be bit-identical");
    }
    server.stop();
    let snap = handle.stop();
    assert_eq!(snap.net_frames, inputs.len() as u64);
    assert_eq!(snap.net_replies, inputs.len() as u64);
    assert_eq!(snap.net_bad_frames, 0);
    assert_eq!(snap.net_dropped_conns, 0);
}

/// Injected `net-drop-conn`: the server kills the connection right after
/// a frame starts arriving — the frame is never accepted, never reaches
/// the pool, and gets no reply; the next connection serves normally.
#[test]
fn injected_conn_drop_closes_without_reply_and_without_pool_traffic() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::NetDropConn, FaultSpec::First(1))
        .build();
    let handle = tiny_handle(ShardConfig::default());
    let server = NetServer::start(
        handle.submitter(),
        "127.0.0.1:0",
        NetConfig { faults: Some(Arc::clone(&plan)), ..NetConfig::default() },
    )
    .unwrap();

    let mut victim = NetClient::connect(server.local_addr()).unwrap();
    victim.send("tiny", &tiny_input()).unwrap();
    match victim.read_reply() {
        Err(_) => {} // connection died: EOF or reset, never a reply
        Ok(r) => panic!("dropped connection must not deliver a reply, got {r:?}"),
    }
    assert_eq!(plan.fired(FaultSite::NetDropConn), 1);

    // Fault exhausted (First(1)): a fresh connection works.
    let mut ok = NetClient::connect(server.local_addr()).unwrap();
    let y = ok.infer("tiny", &tiny_input()).expect("post-fault serving");
    assert_eq!(y.dims(), &[2, 2, 2]);

    server.stop();
    let snap = handle.stop();
    assert_eq!(snap.net_dropped_conns, 1);
    assert_eq!(snap.net_frames, 1, "only the post-fault frame was accepted");
    assert_eq!(snap.total_requests, 1, "the dropped frame never reached the pool");
}

/// Slow-loris: a client trickles half a frame and stalls. The per-frame
/// read deadline disconnects it in bounded time; nothing hangs, nothing
/// reaches the pool.
#[test]
fn slow_loris_partial_frame_hits_the_read_deadline() {
    let handle = tiny_handle(ShardConfig::default());
    let server = NetServer::start(
        handle.submitter(),
        "127.0.0.1:0",
        NetConfig { read_timeout: Duration::from_millis(150), ..NetConfig::default() },
    )
    .unwrap();

    let mut loris = NetClient::connect(server.local_addr()).unwrap();
    let frame = nncg::coordinator::proto::encode_request(
        1,
        "tiny",
        &[8, 8, 1],
        &[0.5; 64],
    )
    .unwrap();
    loris.send_raw(&frame[..frame.len() / 2]).unwrap();
    // Do not send the rest; the server must cut us off near the deadline.
    let t0 = Instant::now();
    match loris.read_reply() {
        Err(_) => {} // disconnected
        Ok(r) => panic!("slow-loris must not be answered, got {r:?}"),
    }
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(10),
        "disconnect must be bounded by the read deadline, waited {waited:?}"
    );

    server.stop();
    let snap = handle.stop();
    assert_eq!(snap.net_dropped_conns, 1, "slow-loris counts as a dropped conn");
    assert_eq!(snap.net_frames, 0, "the partial frame was never accepted");
    assert_eq!(snap.total_requests, 0);
}

/// Injected `net-partial-write`: every response frame is written in two
/// halves with a stall between them — the client must reassemble replies
/// split mid-frame, bit-identically.
#[test]
fn partial_writes_are_reassembled_by_the_client() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::NetPartialWrite, FaultSpec::Every(1))
        .delay(Duration::from_millis(2))
        .build();
    let handle = tiny_handle(ShardConfig::default());
    let server = NetServer::start(
        handle.submitter(),
        "127.0.0.1:0",
        NetConfig { faults: Some(Arc::clone(&plan)), ..NetConfig::default() },
    )
    .unwrap();
    let submitter = handle.submitter();
    let reference = submitter.infer("tiny", tiny_input()).unwrap();

    let mut client = NetClient::connect(server.local_addr()).unwrap();
    for _ in 0..5 {
        let y = client.infer("tiny", &tiny_input()).expect("split reply reassembled");
        assert_eq!(y, reference);
    }
    assert_eq!(plan.fired(FaultSite::NetPartialWrite), 5);
    server.stop();
    let snap = handle.stop();
    assert_eq!(snap.net_replies, 5);
    assert_eq!(snap.net_dropped_conns, 0);
}

/// `stop_with_timeout` under a slow engine: frames still queued when the
/// shutdown deadline fires are answered over the wire with status
/// `Stopped` — every accepted frame gets exactly one reply, none hang.
#[test]
fn stop_with_timeout_answers_in_flight_frames_with_stopped_status() {
    // A 50 ms latency spike on every inference, one worker: a pipelined
    // burst is guaranteed to still be queued when shutdown fires.
    let spike = FaultPlan::builder(chaos_seed())
        .site(FaultSite::LatencySpike, FaultSpec::Every(1))
        .delay(Duration::from_millis(50))
        .build();
    let router = Arc::new(Router::new());
    let slow: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap()),
        spike,
    ));
    router.register("tiny", slow);
    let handle = serve_sharded(
        router,
        ShardConfig { shards: 1, workers_per_shard: 1, ..ShardConfig::default() },
    );
    let server =
        NetServer::start(handle.submitter(), "127.0.0.1:0", NetConfig::default()).unwrap();

    let total = 10u64;
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let mut sent = Vec::new();
    for _ in 0..total {
        sent.push(client.send("tiny", &tiny_input()).unwrap());
    }
    // Wait for the first reply so the burst is definitely admitted, then
    // shut the pool down with a deadline far shorter than the backlog.
    let (first_id, first) = client.read_reply().unwrap();
    assert_eq!(first_id, sent[0]);
    assert!(first.is_ok(), "first reply should be served, got {first:?}");

    server.begin_stop();
    let snap = handle.stop_with_timeout(Duration::from_millis(1));

    let mut ok = 1u64; // the first reply, already read
    let mut stopped = 0u64;
    for expect_id in &sent[1..] {
        let (id, reply) = client.read_reply().expect("every accepted frame is answered");
        assert_eq!(id, *expect_id, "replies arrive in submission order");
        match reply {
            Ok(_) => ok += 1,
            Err(e) => {
                assert_eq!(e.kind(), "stopped", "unexpected error reply: {e}");
                stopped += 1;
            }
        }
    }
    assert_eq!(ok + stopped, total, "exactly one reply per accepted frame");
    assert!(stopped >= 1, "a 1 ms deadline cannot drain a 50 ms/request backlog");
    assert_eq!(
        snap.stopped_replies, stopped,
        "wire Stopped replies must equal the pool's purge count"
    );
    // After the pool stopped, the connection drains and closes.
    server.stop();
}

/// Seeded kill-storm over TCP: shard workers die randomly under pipelined
/// load from several connections, with net fault sites (slow reads,
/// partial writes) exercising the wire at the same time — built from the
/// same `NNCG_FAULTS` vocabulary CI uses. The accounting gate must hold:
/// every submitted frame is answered exactly once (ok, or a typed shed),
/// and nothing is lost.
#[test]
fn kill_storm_over_tcp_holds_the_accounting_gate() {
    let seed = chaos_seed();
    let plan = FaultPlan::parse(&format!(
        "seed={seed},delay-ms=1,shard-kill=prob:0.02,net-slow-read=every:7,net-partial-write=every:5"
    ))
    .expect("net sites parse from the NNCG_FAULTS vocabulary");
    let router = Arc::new(Router::new());
    router.register(
        "tiny",
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap()),
    );
    let handle = serve_sharded(
        router,
        ShardConfig {
            shards: 4,
            workers_per_shard: 1,
            queue_capacity: 4096,
            steal: true,
            steal_policy: StealPolicy::HalfAge,
            faults: Some(Arc::clone(&plan)),
            ..ShardConfig::default()
        },
    );
    let server = NetServer::start(
        handle.submitter(),
        "127.0.0.1:0",
        NetConfig { faults: Some(Arc::clone(&plan)), ..NetConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();

    let clients = 4u64;
    let per_client = 64u64;
    let mut joins = Vec::new();
    for c in 0..clients {
        joins.push(std::thread::spawn(move || {
            let mut client = NetClient::connect(addr).expect("connect");
            let input = tiny_input();
            let window = 16usize;
            let mut submitted = 0u64;
            let mut replied_ok = 0u64;
            let mut shed = 0u64;
            let mut pending = std::collections::VecDeque::new();
            let mut drain =
                |pending: &mut std::collections::VecDeque<u64>,
                 client: &mut NetClient,
                 replied_ok: &mut u64,
                 shed: &mut u64| {
                    let expect = pending.pop_front().expect("pending");
                    let (id, reply) =
                        client.read_reply().expect("accepted frames are always answered");
                    assert_eq!(id, expect, "client {c}: per-connection reply order");
                    match reply {
                        Ok(y) => {
                            assert_eq!(y.dims(), &[2, 2, 2]);
                            *replied_ok += 1;
                        }
                        Err(e) => {
                            // The only acceptable error under a kill-storm
                            // is an admission shed; kills themselves must
                            // be absorbed by respawn + steal.
                            assert_eq!(e.kind(), "queue-full", "client {c}: {e}");
                            *shed += 1;
                        }
                    }
                };
            for _ in 0..per_client {
                pending.push_back(client.send("tiny", &input).expect("send"));
                submitted += 1;
                if pending.len() >= window {
                    drain(&mut pending, &mut client, &mut replied_ok, &mut shed);
                }
            }
            while !pending.is_empty() {
                drain(&mut pending, &mut client, &mut replied_ok, &mut shed);
            }
            (submitted, replied_ok, shed)
        }));
    }
    let mut submitted = 0u64;
    let mut replied_ok = 0u64;
    let mut shed = 0u64;
    for j in joins {
        let (s, r, sh) = j.join().expect("client thread must not panic");
        submitted += s;
        replied_ok += r;
        shed += sh;
    }

    server.stop();
    let snap = handle.stop();
    // The gate: submitted == replied + shed, lost == 0 (lost would have
    // paniced a client thread above).
    assert_eq!(submitted, clients * per_client);
    assert_eq!(submitted, replied_ok + shed, "accounting gate");
    assert_eq!(snap.net_frames, submitted, "every frame accepted");
    assert_eq!(snap.net_replies, submitted, "every frame answered over the wire");
    assert_eq!(snap.net_bad_frames, 0);
    assert_eq!(snap.net_dropped_conns, 0);
}

/// Satellite regression: a storm of unknown-model frames is rejected
/// *before* the pool — zero shard-queue slots consumed, zero pool
/// requests executed, queues empty — and the same connection still
/// serves a registered model afterwards.
#[test]
fn unknown_model_storm_leaves_queue_depth_and_in_flight_at_zero() {
    let handle = tiny_handle(ShardConfig {
        shards: 2,
        workers_per_shard: 1,
        ..ShardConfig::default()
    });
    let server =
        NetServer::start(handle.submitter(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let storm = 100u64;
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let ghost_input = Tensor::from_vec(&[2, 2], vec![0.0; 4]).unwrap();
    let mut pending = std::collections::VecDeque::new();
    for i in 0..storm {
        pending.push_back(client.send(&format!("ghost-{i}"), &ghost_input).unwrap());
        // Pipeline up to the window, then drain one.
        if pending.len() >= 32 {
            let expect = pending.pop_front().unwrap();
            let (id, reply) = client.read_reply().unwrap();
            assert_eq!(id, expect);
            let err = reply.expect_err("unknown model must be rejected");
            assert_eq!(err.kind(), "model-unknown");
            assert!(err.message.contains("tiny"), "lists registered models: {}", err.message);
        }
    }
    while let Some(expect) = pending.pop_front() {
        let (id, reply) = client.read_reply().unwrap();
        assert_eq!(id, expect);
        assert_eq!(reply.expect_err("rejected").kind(), "model-unknown");
    }

    // Same connection, known model: still served.
    let y = client.infer("tiny", &tiny_input()).expect("known model after storm");
    assert_eq!(y.dims(), &[2, 2, 2]);

    server.stop();
    let snap = handle.stop();
    assert_eq!(snap.net_unknown_rejects, storm);
    assert_eq!(snap.total_requests, 1, "only the known-model frame reached the pool");
    assert_eq!(snap.queue_full_sheds, 0, "no shard-queue slot was consumed");
    for s in &snap.shards {
        assert_eq!(s.queue_len, 0, "shard {} queue must be empty", s.idx);
    }
    assert_eq!(snap.net_frames, storm + 1);
    assert_eq!(snap.net_replies, storm + 1, "every rejection is still a reply");
}

/// The submitter used by the net server correctly reports registry
/// membership (the pre-submission gate's primitive).
#[test]
fn submitter_has_model_tracks_the_router() {
    let router = Arc::new(Router::new());
    let handle = serve_sharded(Arc::clone(&router), ShardConfig::default());
    let submitter = handle.submitter();
    assert!(!submitter.has_model("tiny"));
    router.register(
        "tiny",
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap()),
    );
    assert!(submitter.has_model("tiny"), "hot registration is visible immediately");
    assert_eq!(submitter.registered_models(), vec!["tiny".to_string()]);
    handle.stop();
}

/// `NetError` surfaces the remote taxonomy: an unknown model infer()
/// returns `NetError::Remote` whose kind matches `ServeError::kind()`.
#[test]
fn net_error_remote_kind_matches_serve_error_kind() {
    let handle = tiny_handle(ShardConfig::default());
    let server =
        NetServer::start(handle.submitter(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let err = client
        .infer("ghost", &Tensor::from_vec(&[1], vec![0.0]).unwrap())
        .expect_err("unknown model");
    match err {
        NetError::Remote(remote) => {
            assert_eq!(
                remote.kind(),
                ServeError::ModelUnknown { model: "ghost".into(), registered: vec![] }.kind()
            );
        }
        other => panic!("expected NetError::Remote, got {other:?}"),
    }
    server.stop();
    handle.stop();
}
