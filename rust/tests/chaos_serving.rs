//! Deterministic chaos suite for the fault-tolerant serving layer.
//!
//! Every scenario runs under a seeded `FaultPlan` (`NNCG_CHAOS_SEED`
//! selects the seed; CI runs a fixed 3-seed matrix) and asserts the
//! acceptance criteria of the robustness layer:
//!
//! * **exactly one reply** per submitted request, under injected panics,
//!   failures, latency storms, and load shedding;
//! * **bit-identical fallback**: degraded replies equal the interpreter
//!   reference exactly;
//! * **breaker transitions** closed → open → half-open → closed;
//! * **full recovery**: after faults stop, the native generated-C engine is
//!   (re-)registered and serves again.
//!
//! The compile-pipeline scenarios use the real host compiler: injected
//! hangs are a `sleep` child the wall-clock timeout must actually kill.

use nncg::cc::{CcDriver, CompileLimits, CompileStats, CompiledCnn};
use nncg::codegen::CodegenOptions;
use nncg::coordinator::{
    serve_with, BreakerConfig, BreakerState, FallbackEngine, Router, ServeConfig, ServeError,
};
use nncg::faults::{FaultPlan, FaultSite, FaultSpec, FaultyEngine};
use nncg::graph::zoo;
use nncg::interp::InterpEngine;
use nncg::runtime::InferenceEngine;
use nncg::tensor::Tensor;
use nncg::util::XorShift64;
use std::sync::Arc;
use std::time::Duration;

/// Seed for this run's fault plans (CI matrix: 1, 2, 3).
fn chaos_seed() -> u64 {
    std::env::var("NNCG_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

fn interp_engine(weight_seed: u64) -> Arc<dyn InferenceEngine> {
    Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(weight_seed)).unwrap())
}

fn workdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("nncg-chaos-{tag}-seed{}", chaos_seed()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Acceptance: every submitted request receives exactly one reply while
/// panics, failures, and latency spikes batter the engine — then the
/// healthy engine is re-registered and throughput fully recovers.
#[test]
fn exactly_one_reply_under_chaos_then_recovery() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::EnginePanic, FaultSpec::Prob(0.25))
        .site(FaultSite::EngineFail, FaultSpec::Prob(0.2))
        .site(FaultSite::LatencySpike, FaultSpec::Every(7))
        .delay(Duration::from_millis(2))
        .build();
    let chaotic: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp_engine(3), plan));
    let router = Arc::new(Router::new());
    router.register("tiny", chaotic);
    let handle = serve_with(
        Arc::clone(&router),
        ServeConfig { workers: 2, queue_capacity: 64, default_deadline: None },
    );

    let mut rng = XorShift64::new(chaos_seed());
    let total = 200usize;
    let mut outcomes = 0usize;
    let mut receivers = Vec::new();
    for _ in 0..total {
        let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
        match handle.submit("tiny", x, None) {
            Ok(rx) => receivers.push(rx),
            // A typed shed at submission *is* this request's one reply.
            Err(ServeError::QueueFull { .. }) => outcomes += 1,
            Err(other) => panic!("unexpected submission error: {other:?}"),
        }
    }
    for rx in receivers {
        // recv_timeout: a lost reply must fail the test, not hang it.
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply lost");
        match reply {
            Ok(y) => assert_eq!(y.dims(), &[2, 2, 2]),
            Err(ServeError::EngineFailed { .. }) => {}
            Err(other) => panic!("unexpected reply error: {other:?}"),
        }
        outcomes += 1;
    }
    assert_eq!(outcomes, total, "exactly one outcome per submission");

    // Recovery: swap in a healthy engine; a burst must be fully correct.
    let healthy = interp_engine(3);
    let x = Tensor::zeros(&[8, 8, 1]);
    let reference = healthy.infer(&x).unwrap();
    router.register("tiny", healthy);
    let outs = handle.infer_burst("tiny", vec![x.clone(); 20]).unwrap();
    assert_eq!(outs.len(), 20);
    for y in outs {
        assert_eq!(y, reference, "post-fault replies are bit-identical to the healthy engine");
    }
    let snap = handle.stop();
    assert!(snap.engine_panics + snap.engine_failures > 0, "the plan must have actually bitten");
    assert_eq!(snap.worker_respawns, 0, "per-request isolation keeps workers alive");
}

/// Acceptance: degraded replies are bit-identical to the interpreter
/// reference, and the breaker walks closed → open → half-open → closed.
#[test]
fn fallback_is_bit_identical_and_breaker_walks_the_full_cycle() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::EngineFail, FaultSpec::First(3))
        .build();
    let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp_engine(10), plan));
    let fallback = interp_engine(11);
    let router = Arc::new(Router::new());
    let handle = serve_with(Arc::clone(&router), ServeConfig::default());
    let wrapped = Arc::new(
        FallbackEngine::new(
            primary,
            Arc::clone(&fallback),
            BreakerConfig { failure_threshold: 3, cooldown: Duration::from_millis(250) },
        )
        .with_counters(Arc::clone(handle.metrics.counters())),
    );
    router.register("tiny", Arc::clone(&wrapped) as Arc<dyn InferenceEngine>);

    let x = Tensor::zeros(&[8, 8, 1]);
    let fallback_ref = fallback.infer(&x).unwrap();
    let primary_ref = interp_engine(10).infer(&x).unwrap();
    assert_ne!(fallback_ref, primary_ref, "distinct weights so we can tell who served");

    assert_eq!(wrapped.breaker().state(), BreakerState::Closed);
    // Three failing calls: all served by the fallback, bit-identical.
    for i in 0..3 {
        let y = handle.infer("tiny", x.clone()).unwrap();
        assert_eq!(y, fallback_ref, "degraded reply {i} must equal the interpreter exactly");
    }
    assert_eq!(wrapped.breaker().state(), BreakerState::Open, "threshold 3 reached");
    // While open (cooldown not elapsed): still the fallback, primary untouched.
    let y = handle.infer("tiny", x.clone()).unwrap();
    assert_eq!(y, fallback_ref);
    assert_eq!(wrapped.breaker().state(), BreakerState::Open);

    // After the cooldown a half-open probe is admitted; the fault plan is
    // exhausted (First(3)), so the probe succeeds and the breaker closes.
    std::thread::sleep(Duration::from_millis(300));
    let y = handle.infer("tiny", x.clone()).unwrap();
    assert_eq!(y, primary_ref, "successful probe reply comes from the primary");
    assert_eq!(wrapped.breaker().state(), BreakerState::Closed);

    let snap = handle.stop();
    assert_eq!(snap.breaker_opens, 1);
    assert_eq!(snap.breaker_half_opens, 1);
    assert_eq!(snap.breaker_closes, 1);
    assert_eq!(snap.fallback_served, 4);
    assert_eq!(snap.degraded, 0, "the fallback itself never failed");
}

/// Deadlines shed stale frames; the bounded queue sheds overload — both
/// with typed errors, and accepted requests still all get served.
#[test]
fn deadline_and_queue_shedding_are_typed_and_lossless() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::LatencySpike, FaultSpec::Every(1))
        .delay(Duration::from_millis(40))
        .build();
    let slow: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp_engine(3), plan));
    let router = Arc::new(Router::new());
    router.register("tiny", slow);
    let handle = serve_with(
        Arc::clone(&router),
        ServeConfig { workers: 1, queue_capacity: 2, default_deadline: None },
    );

    let x = || Tensor::zeros(&[8, 8, 1]);
    // r1 occupies the worker (~40ms); give it time to be dequeued.
    let r1 = handle.submit("tiny", x(), None).unwrap();
    std::thread::sleep(Duration::from_millis(15));
    // r2 waits in the queue with a 5ms deadline — expired by dequeue time.
    let r2 = handle.submit("tiny", x(), Some(Duration::from_millis(5))).unwrap();
    let r3 = handle.submit("tiny", x(), None).unwrap();
    // Queue (capacity 2) now holds r2+r3: further submissions shed.
    let mut queue_sheds = 0;
    for _ in 0..2 {
        match handle.submit("tiny", x(), None) {
            Err(ServeError::QueueFull { capacity: 2 }) => queue_sheds += 1,
            other => panic!("expected QueueFull, got {other:?}"),
        }
    }
    assert!(r1.recv_timeout(Duration::from_secs(10)).unwrap().is_ok());
    match r2.recv_timeout(Duration::from_secs(10)).unwrap() {
        Err(ServeError::DeadlineExceeded { model, late_by_us }) => {
            assert_eq!(model, "tiny");
            assert!(late_by_us > 0);
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(r3.recv_timeout(Duration::from_secs(10)).unwrap().is_ok());

    let snap = handle.stop();
    assert_eq!(snap.deadline_sheds, 1);
    assert_eq!(snap.queue_full_sheds, queue_sheds);
    assert_eq!(snap.total_requests, 2, "only r1 and r3 consumed compute");
}

/// Acceptance (compile pipeline): injected transient failure, then a hung
/// compiler the wall-clock timeout must kill, then the real compiler
/// succeeds — and a later cache hit survives injected corruption.
#[test]
fn compile_timeout_retry_and_cache_corruption_heal() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::CompileFail, FaultSpec::First(1))
        .site(FaultSite::CompileSlow, FaultSpec::First(1))
        .site(FaultSite::CacheCorrupt, FaultSpec::First(1))
        .delay(Duration::from_secs(30))
        .build();
    let driver = CcDriver::detect()
        .unwrap()
        .with_faults(Arc::clone(&plan))
        .with_limits(CompileLimits {
            timeout: Duration::from_millis(200),
            max_retries: 3,
            backoff_base: Duration::from_millis(1),
        });

    let model = zoo::tiny_test_net().with_random_weights(1234);
    let opts = CodegenOptions::general();
    let dir = workdir("compile");
    let _ = std::fs::remove_dir_all(&dir);

    // Attempt 1: injected transient failure. Attempt 2: sleep-child hang,
    // killed at 200ms. Attempt 3: the real compiler.
    let t0 = std::time::Instant::now();
    let cnn = CompiledCnn::build_with(&model, &opts, &dir, &driver).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(20), "hung compile must be killed, not awaited");
    let stats = driver.stats();
    assert_eq!(CompileStats::get(&stats.attempts), 3);
    assert_eq!(CompileStats::get(&stats.retries), 2);
    assert_eq!(CompileStats::get(&stats.timeouts), 1);
    assert_eq!(CompileStats::get(&stats.failures), 0);

    let x = Tensor::zeros(&[8, 8, 1]);
    let reference = nncg::interp::run(&model, &x).unwrap();
    let y = cnn.infer(&x).unwrap();
    assert!(reference.max_abs_diff(&y).unwrap() < 1e-5);

    // Cache hit path: injected corruption is detected and recompiled.
    let cnn2 = CompiledCnn::build_with(&model, &opts, &dir, &driver).unwrap();
    assert_eq!(plan.fired(FaultSite::CacheCorrupt), 1, "corruption must have been injected");
    assert_eq!(CompileStats::get(&stats.attempts), 4, "corrupted object forces one recompile");
    let y2 = cnn2.infer(&x).unwrap();
    assert!(reference.max_abs_diff(&y2).unwrap() < 1e-5);
}

/// Acceptance (full story): dlopen failure at startup degrades to the
/// interpreter; a background heal rebuilds the native engine and hot-swaps
/// it via `Router::register`; post-fault traffic runs on generated C.
#[test]
fn dlopen_failure_degrades_then_heals_to_native_engine() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::DlopenFail, FaultSpec::First(1))
        .build();
    let driver =
        Arc::new(CcDriver::detect().unwrap().with_faults(Arc::clone(&plan)));
    let model = zoo::tiny_test_net().with_random_weights(77);
    let opts = CodegenOptions::general();
    let dir = workdir("dlopen");
    let _ = std::fs::remove_dir_all(&dir);

    // Startup: the native build fails at the dlopen seam.
    let err = CompiledCnn::build_with(&model, &opts, &dir, &driver).unwrap_err();
    assert!(format!("{err:#}").contains("dlopen"), "{err:#}");

    // Degrade: serve from the interpreter while unhealthy.
    let interp: Arc<dyn InferenceEngine> = Arc::new(InterpEngine::new(model.clone()).unwrap());
    let router = Arc::new(Router::new());
    router.register("tiny", Arc::clone(&interp));
    let handle = serve_with(Arc::clone(&router), ServeConfig::default());
    let x = Tensor::zeros(&[8, 8, 1]);
    let reference = interp.infer(&x).unwrap();
    assert_eq!(handle.infer("tiny", x.clone()).unwrap(), reference);
    assert_eq!(router.engine("tiny").unwrap().name(), "interp");

    // Heal in the background: the fault is exhausted, the rebuild succeeds,
    // and the native engine hot-swaps in through the same Router.
    let heal = {
        let router = Arc::clone(&router);
        let model = model.clone();
        let driver = Arc::clone(&driver);
        let dir = dir.clone();
        std::thread::spawn(move || {
            let native = CompiledCnn::build_with(&model, &opts, &dir, &driver)?;
            router.register("tiny", Arc::new(native));
            anyhow::Result::<()>::Ok(())
        })
    };
    heal.join().unwrap().unwrap();
    assert_eq!(router.engine("tiny").unwrap().name(), "tiny", "native engine re-registered");

    // Recovered: served by generated C, numerically equal to the interpreter.
    let y = handle.infer("tiny", x.clone()).unwrap();
    assert!(reference.max_abs_diff(&y).unwrap() < 1e-5);
    let snap = handle.stop();
    assert_eq!(snap.errors, 0, "no request was lost or failed across the heal");
}

/// A fault plan is deterministic for a given seed: two identical serving
/// runs produce identical injection sequences and identical counters.
#[test]
fn chaos_runs_are_reproducible_per_seed() {
    let run = || {
        let plan = FaultPlan::builder(chaos_seed())
            .site(FaultSite::EngineFail, FaultSpec::Prob(0.3))
            .build();
        let eng = FaultyEngine::new(interp_engine(3), Arc::clone(&plan));
        let x = Tensor::zeros(&[8, 8, 1]);
        let pattern: Vec<bool> = (0..64).map(|_| eng.infer(&x).is_ok()).collect();
        (pattern, plan.fired(FaultSite::EngineFail))
    };
    let (pat_a, fired_a) = run();
    let (pat_b, fired_b) = run();
    assert_eq!(pat_a, pat_b, "same seed, same injection sequence");
    assert_eq!(fired_a, fired_b);
    assert!(fired_a > 0, "p=0.3 over 64 calls must fire");
}
