//! Deterministic chaos suite for the *sharded* serving coordinator.
//!
//! Extends the PR 6 chaos contract (`chaos_serving.rs`, which runs
//! unchanged against the sharded build via `NNCG_SERVE_SHARDS`) with the
//! shard-level failure modes:
//!
//! * **exactly one reply** per accepted request while a shard's worker is
//!   repeatedly killed between requests and its backlog is stolen by
//!   idle peers — with every served reply bit-identical to the
//!   interpreter reference;
//! * **shard lifecycle**: a sick shard is ejected from routing by its
//!   breaker, probed half-open after the cooldown, and re-admitted —
//!   while the other shard keeps serving and no request is lost;
//! * **graceful drain/restart** of a shard under live traffic with zero
//!   dropped accepted requests;
//! * **steal races** (injected `steal-race` delays) never drop or
//!   duplicate a reply;
//! * the **heal pipeline** rebuilds a model in the background (real
//!   `CcDriver` compile when the host has a C compiler, interpreter
//!   rebuild otherwise) and hot-swaps it without losing in-flight
//!   traffic.
//!
//! Every scenario is seeded (`NNCG_CHAOS_SEED`; CI runs seeds 1-3 × shard
//! counts 1 and 4 for the compat suite, and this suite once per seed).

use nncg::cc::{CcDriver, CompileLimits, CompiledCnn};
use nncg::codegen::CodegenOptions;
use nncg::coordinator::{
    home_shard, serve_sharded, BreakerConfig, HealPipeline, Router, ServeError, ShardConfig,
};
use nncg::faults::{FaultPlan, FaultSite, FaultSpec};
use nncg::graph::zoo;
use nncg::interp::InterpEngine;
use nncg::runtime::InferenceEngine;
use nncg::tensor::Tensor;
use nncg::util::XorShift64;
use std::sync::Arc;
use std::time::Duration;

/// Seed for this run's fault plans (CI matrix: 1, 2, 3).
fn chaos_seed() -> u64 {
    std::env::var("NNCG_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

fn interp_engine(weight_seed: u64) -> Arc<dyn InferenceEngine> {
    Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(weight_seed)).unwrap())
}

/// Acceptance (tentpole): a shard dies mid-flight — its worker is killed
/// ten times between requests — and its queued backlog is stolen by idle
/// peers. Every accepted request gets exactly one reply, every reply is
/// bit-identical to the interpreter reference, and nothing is lost or
/// duplicated.
#[test]
fn exactly_one_reply_while_home_shard_dies_and_queue_is_stolen() {
    let shards = 4usize;
    let home = home_shard("tiny", shards);
    // Kill only the home shard's worker, at the top of its loop (never
    // with a request in hand), ten times in a row: a ~20ms death storm
    // right at startup while the backlog lands on its queue.
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::ShardKill, FaultSpec::First(10))
        .target_shard(home)
        .build();
    let router = Arc::new(Router::new());
    router.register("tiny", interp_engine(3));
    let reference = interp_engine(3);
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig {
            shards,
            workers_per_shard: 1,
            queue_capacity: 4096,
            steal: true,
            // Keep the shard routable: this scenario isolates steal +
            // respawn; ejection is exercised separately below.
            breaker: BreakerConfig { failure_threshold: 1000, cooldown: Duration::from_millis(50) },
            faults: Some(plan),
            ..ShardConfig::default()
        },
    );

    let mut rng = XorShift64::new(chaos_seed());
    let total = 300usize;
    let inputs: Vec<Tensor> = (0..total).map(|_| Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng)).collect();
    let receivers: Vec<_> = inputs
        .iter()
        .map(|x| handle.submit("tiny", x.clone(), None).expect("queue sized for the full burst"))
        .collect();

    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply lost");
        let y = reply.expect("kills never consume a request: all served");
        let want = reference.infer(&inputs[i]).unwrap();
        assert_eq!(y, want, "reply {i} must be bit-identical to the interpreter");
        assert!(rx.try_recv().is_err(), "no second reply for request {i}");
    }

    let snap = handle.stop();
    assert_eq!(snap.total_requests, total as u64);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.worker_respawns, 10, "deterministic First(10) kill storm");
    assert_eq!(snap.shards.len(), shards);
    assert_eq!(snap.shards[home].respawns, 10, "all kills land on the target shard");
    assert!(snap.steals > 0, "peers must steal the dead shard's backlog");
    assert!(
        snap.shards.iter().enumerate().any(|(i, s)| i != home && s.stolen_by > 0),
        "at least one peer shard executed stolen work: {:?}",
        snap.shards
    );
}

/// Acceptance: shard lifecycle closed → ejected → probing → readmitted.
/// A kill storm trips the home shard's breaker (ejected from routing);
/// the peer shard serves while it is out; after the cooldown one request
/// probes it half-open, succeeds, and re-admits it. No request is lost
/// at any point, and the *engine-level* breaker counters stay untouched.
#[test]
fn sick_shard_is_ejected_probed_and_readmitted() {
    let shards = 2usize;
    let home = home_shard("tiny", shards);
    let peer = 1 - home;
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::ShardKill, FaultSpec::First(6))
        .target_shard(home)
        .build();
    let router = Arc::new(Router::new());
    router.register("tiny", interp_engine(3));
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig {
            shards,
            workers_per_shard: 1,
            queue_capacity: 1024,
            // Stealing off: requests must stay where routing put them so
            // the ejection window is observable per shard.
            steal: false,
            breaker: BreakerConfig { failure_threshold: 4, cooldown: Duration::from_millis(60) },
            faults: Some(plan),
            ..ShardConfig::default()
        },
    );

    // Let the kill storm trip the breaker (6 kills ≈ 15ms; it opens at
    // the 4th), then serve through the ejection + readmission window.
    std::thread::sleep(Duration::from_millis(25));
    let mut rng = XorShift64::new(chaos_seed());
    let total = 30usize;
    for i in 0..total {
        let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
        let y = handle.infer("tiny", x);
        assert!(y.is_ok(), "request {i} lost during ejection window: {y:?}");
        std::thread::sleep(Duration::from_millis(5));
    }

    let snap = handle.stop();
    assert_eq!(snap.total_requests, total as u64);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.worker_respawns, 6);
    assert_eq!(snap.shards[home].respawns, 6);
    assert!(snap.shard_ejects >= 1, "kill storm must eject the home shard");
    assert!(snap.shard_probes >= 1, "cooldown must admit a half-open probe");
    assert!(snap.shard_readmits >= 1, "successful probe must re-admit the shard");
    assert!(snap.shards[peer].handled > 0, "peer serves while home is ejected");
    assert!(snap.shards[home].handled > 0, "home serves again after readmission");
    assert_eq!(snap.breaker_opens, 0, "engine-level breaker counters stay untouched");
    assert_eq!(snap.breaker_closes, 0);
    assert!(snap.sickest_shard().map(|s| s.idx) == Some(home), "home is the sickest shard");
}

/// Acceptance: a shard is drained and restarted under live traffic with
/// zero dropped accepted requests — submissions reroute to the peer while
/// the shard drains, and come back after the restart.
#[test]
fn drain_and_restart_under_live_traffic_loses_nothing() {
    let shards = 2usize;
    let home = home_shard("tiny", shards);
    let peer = 1 - home;
    let router = Arc::new(Router::new());
    router.register("tiny", interp_engine(3));
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig { shards, workers_per_shard: 1, queue_capacity: 4096, steal: false, ..ShardConfig::default() },
    );

    assert!(!handle.recycle_shard(99), "unknown shard index is rejected");

    let submitter = handle.submitter();
    let total = 200usize;
    let pump = std::thread::spawn(move || {
        let mut rng = XorShift64::new(chaos_seed());
        let mut receivers = Vec::with_capacity(total);
        for _ in 0..total {
            let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
            receivers.push(submitter.submit("tiny", x, None).expect("admission stays open"));
            std::thread::sleep(Duration::from_micros(200));
        }
        receivers
    });

    // Recycle the home shard mid-stream: blocks until its backlog is
    // served, its old worker retired, and a fresh one spawned.
    std::thread::sleep(Duration::from_millis(15));
    assert!(handle.recycle_shard(home), "recycle must succeed");

    let receivers = pump.join().unwrap();
    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply lost");
        assert!(reply.is_ok(), "request {i} dropped across the drain: {reply:?}");
        assert!(rx.try_recv().is_err(), "no second reply for request {i}");
    }

    let snap = handle.stop();
    assert_eq!(snap.total_requests, total as u64);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.shard_drains, 1);
    assert_eq!(snap.shards[home].drains, 1);
    assert!(snap.shards[peer].handled > 0, "traffic rerouted to the peer during the drain");
    assert!(snap.shards[home].handled > 0, "home served before and/or after the restart");
}

/// Acceptance: injected steal-race delays (thief sleeps between choosing
/// a victim and stealing, so thieves race each other and the owner) never
/// drop or duplicate a reply.
#[test]
fn steal_races_never_drop_or_duplicate_replies() {
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::StealRace, FaultSpec::Every(1))
        .delay(Duration::from_millis(2))
        .build();
    let router = Arc::new(Router::new());
    router.register("tiny", interp_engine(3));
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig {
            shards: 4,
            workers_per_shard: 1,
            queue_capacity: 4096,
            steal: true,
            faults: Some(plan),
            ..ShardConfig::default()
        },
    );

    // One big burst to a single model: everything lands on the home
    // shard, and the three idle peers race to steal it.
    let mut rng = XorShift64::new(chaos_seed());
    let total = 2000usize;
    let receivers: Vec<_> = (0..total)
        .map(|_| {
            let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
            handle.submit("tiny", x, None).expect("queue sized for the burst")
        })
        .collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply lost");
        assert!(reply.is_ok(), "request {i}: {reply:?}");
        assert!(rx.try_recv().is_err(), "no second reply for request {i}");
    }

    let snap = handle.stop();
    assert_eq!(snap.total_requests, total as u64);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.worker_respawns, 0);
    assert!(snap.steals > 0, "the burst must actually have been contended");
}

/// Acceptance (PR 9): an engine panic in the middle of a *batched*
/// `infer_batch` dispatch fails only that batch's requests — every member
/// of the panicking batch gets exactly one typed `EngineFailed` reply,
/// every other request is served normally, and nothing is lost or
/// duplicated.
#[test]
fn mid_batch_engine_panic_fails_only_that_batch_with_one_reply_each() {
    let batch_cap = 4usize;
    let total = 12usize;
    // The panic site is consulted once per image, so First(1) detonates on
    // the first image of the first dispatched batch.
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::EnginePanic, FaultSpec::First(1))
        .build();
    let faulty: Arc<dyn InferenceEngine> =
        Arc::new(nncg::faults::FaultyEngine::new(interp_engine(3), plan));
    let router = Arc::new(Router::new());
    router.register("tiny", faulty);
    let reference = interp_engine(3);
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 4096,
            steal: false,
            // Fixed width-4 batches with a generous fill wait, so the
            // burst below is dequeued as real multi-request batches.
            batch: nncg::coordinator::BatcherPolicy::batched(batch_cap, Duration::from_millis(100)),
            ..ShardConfig::default()
        },
    );

    let mut rng = XorShift64::new(chaos_seed());
    let inputs: Vec<Tensor> =
        (0..total).map(|_| Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng)).collect();
    let receivers: Vec<_> = inputs
        .iter()
        .map(|x| handle.submit("tiny", x.clone(), None).expect("queue sized for the burst"))
        .collect();

    let mut served = 0usize;
    let mut failed = 0usize;
    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply lost");
        match reply {
            Ok(y) => {
                let want = reference.infer(&inputs[i]).unwrap();
                assert_eq!(y, want, "served reply {i} must be bit-identical");
                served += 1;
            }
            Err(ServeError::EngineFailed { reason, .. }) => {
                assert!(reason.contains("panicked"), "typed panic reply, got: {reason}");
                failed += 1;
            }
            Err(other) => panic!("request {i}: unexpected reply {other:?}"),
        }
        assert!(rx.try_recv().is_err(), "no second reply for request {i}");
    }

    assert_eq!(served + failed, total, "exactly one reply per accepted request");
    assert!(failed >= 1, "the injected panic must fail its batch");
    assert!(failed <= batch_cap, "blast radius is one batch, {failed} > {batch_cap}");
    let snap = handle.stop();
    assert_eq!(snap.total_requests, total as u64);
    assert_eq!(snap.errors, failed as u64);
    assert_eq!(snap.engine_panics, 1, "one panicking dispatch");
    assert!(snap.batched_infers >= 1, "the burst must dispatch real batches");
    assert!(snap.batch_size_max <= batch_cap as u64, "width capped by policy");
    assert_eq!(snap.worker_respawns, 0, "the panic is contained; no worker dies");
}

/// Acceptance: `stop_with_timeout` on a wedged sharded pool answers every
/// still-queued request with a typed `Stopped` reply instead of hanging.
#[test]
fn stop_with_timeout_answers_backlog_with_typed_stopped() {
    // A deliberately slow engine: each request parks its worker ~80ms.
    let plan = FaultPlan::builder(chaos_seed())
        .site(FaultSite::LatencySpike, FaultSpec::Every(1))
        .delay(Duration::from_millis(80))
        .build();
    let slow: Arc<dyn InferenceEngine> =
        Arc::new(nncg::faults::FaultyEngine::new(interp_engine(3), plan));
    let router = Arc::new(Router::new());
    router.register("tiny", slow);
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig { shards: 2, workers_per_shard: 1, queue_capacity: 64, steal: false, ..ShardConfig::default() },
    );

    let total = 6usize;
    let receivers: Vec<_> = (0..total)
        .map(|_| handle.submit("tiny", Tensor::zeros(&[8, 8, 1]), None).unwrap())
        .collect();
    std::thread::sleep(Duration::from_millis(20));
    let t0 = std::time::Instant::now();
    let snap = handle.stop_with_timeout(Duration::from_millis(120));
    assert!(t0.elapsed() < Duration::from_secs(3), "deadline stop must not hang");

    let mut served = 0u64;
    let mut stopped = 0u64;
    for rx in receivers {
        match rx.recv().unwrap_or(Err(ServeError::Stopped)) {
            Ok(_) => served += 1,
            Err(ServeError::Stopped) => stopped += 1,
            Err(other) => panic!("unexpected reply: {other:?}"),
        }
    }
    assert_eq!(served + stopped, total as u64, "exactly one reply per accepted request");
    assert!(served >= 1, "in-flight work finishes inside the grace window");
    assert!(stopped >= 1, "deep backlog answered with typed Stopped");
    assert_eq!(snap.stopped_replies, stopped);
}

/// Acceptance: the per-model heal pipeline rebuilds in the background —
/// with the real `CcDriver` under `CompileLimits` when the host has a C
/// compiler, an interpreter rebuild otherwise — and hot-swaps via
/// `Router::register` without losing any in-flight traffic.
#[test]
fn heal_pipeline_recompiles_and_hot_swaps_under_live_traffic() {
    let model = zoo::tiny_test_net().with_random_weights(3);
    let interp: Arc<dyn InferenceEngine> = Arc::new(InterpEngine::new(model.clone()).unwrap());
    let router = Arc::new(Router::new());
    router.register("tiny", Arc::clone(&interp));
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig { shards: 2, workers_per_shard: 1, queue_capacity: 4096, ..ShardConfig::default() },
    );
    let heal = HealPipeline::new(Arc::clone(&router))
        .with_counters(Arc::clone(handle.metrics.counters()));

    // Live traffic racing the rebuild + hot swap.
    let submitter = handle.submitter();
    let traffic = std::thread::spawn(move || {
        let mut rng = XorShift64::new(chaos_seed());
        let mut okays = 0usize;
        for _ in 0..200 {
            let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
            if submitter.infer("tiny", x).is_ok() {
                okays += 1;
            }
        }
        okays
    });

    let m = model.clone();
    let accepted = heal.request_rebuild("tiny", move || {
        match CcDriver::detect() {
            Ok(driver) => {
                let driver = driver.with_limits(CompileLimits {
                    timeout: Duration::from_secs(60),
                    max_retries: 1,
                    backoff_base: Duration::from_millis(10),
                });
                let dir = std::env::temp_dir().join(format!("nncg-heal-sharded-seed{}", chaos_seed()));
                std::fs::create_dir_all(&dir).map_err(|e| anyhow::anyhow!("mkdir: {e}"))?;
                let cnn = CompiledCnn::build_with(&m, &CodegenOptions::sse3(), &dir, &driver)?;
                Ok(Arc::new(cnn) as Arc<dyn InferenceEngine>)
            }
            // No host compiler: heal back to a fresh interpreter so the
            // pipeline mechanics are still exercised end to end.
            Err(_) => Ok(Arc::new(InterpEngine::new(m.clone())?) as Arc<dyn InferenceEngine>),
        }
    });
    assert!(accepted, "free slot must accept the rebuild");
    assert_eq!(heal.wait_idle(), 1, "exactly one successful heal");

    let okays = traffic.join().unwrap();
    assert_eq!(okays, 200, "no request lost across the hot swap");

    // The healed engine (generated C or interpreter) is bit-identical.
    let mut rng = XorShift64::new(chaos_seed() + 1);
    let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
    let want = interp.infer(&x).unwrap();
    let got = handle.infer("tiny", x).unwrap();
    assert_eq!(got, want, "healed engine serves bit-identical results");

    let snap = handle.stop();
    assert_eq!(snap.heals_started, 1);
    assert_eq!(snap.heals_succeeded, 1);
    assert_eq!(snap.heals_failed, 0);
    assert_eq!(snap.errors, 0);
}
