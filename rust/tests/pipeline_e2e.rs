//! End-to-end pipeline tests: full serving stack over synthetic frames,
//! model save/load/deploy round trips, and failure injection.

use nncg::cc::CompiledCnn;
use nncg::codegen::CodegenOptions;
use nncg::coordinator;
use nncg::experiments::{default_weights_dir, default_work_dir, load_model};
use nncg::graph::zoo;
use nncg::interp::InterpEngine;
use nncg::tensor::Tensor;
use nncg::util::XorShift64;
use nncg::vision::{ball, nms, render};
use std::sync::Arc;

/// Frame → candidates → classify → NMS through the coordinator with the
/// generated-C engine. The structural assertion is that every candidate
/// gets classified and metrics add up.
#[test]
fn frame_pipeline_end_to_end_with_generated_c() {
    let model = load_model("ball", &default_weights_dir()).unwrap();
    let cnn = CompiledCnn::build(&model, &CodegenOptions::sse3(), default_work_dir()).unwrap();
    let handle = coordinator::serve_single("ball", Arc::new(cnn), 2);

    let mut rng = XorShift64::new(31);
    let mut total = 0usize;
    for _ in 0..5 {
        let (img, _) = render::soccer_frame(60, 80, 2, 1, &mut rng);
        let cands = ball::extract_candidates(&img, &ball::BallExtractorConfig::default());
        let patches: Vec<Tensor> = cands.iter().map(|c| ball::candidate_patch(&img, c)).collect();
        total += patches.len();
        if patches.is_empty() {
            continue;
        }
        let outs = handle.infer_burst("ball", patches).unwrap();
        assert_eq!(outs.len(), cands.len());
        let dets: Vec<_> = cands
            .iter()
            .zip(&outs)
            .map(|(c, o)| ball::to_detection(c, o.data()[1]))
            .collect();
        let kept = nms(dets.clone(), 0.3);
        assert!(kept.len() <= dets.len());
    }
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.total_requests as usize, total);
    assert_eq!(snap.errors, 0);
    handle.shutdown();
}

/// Save → load → generate → compile → infer must agree with the original.
#[test]
fn save_load_codegen_round_trip() {
    let dir = std::env::temp_dir().join("nncg-e2e-roundtrip");
    let model = zoo::pedestrian_classifier().with_random_weights(88);
    nncg::model::save(&model, &dir.join("pedestrian")).unwrap();
    let loaded = nncg::model::load(&dir.join("pedestrian")).unwrap();

    let cnn_a = CompiledCnn::build(&model, &CodegenOptions::sse3(), &dir).unwrap();
    let cnn_b = CompiledCnn::build(&loaded, &CodegenOptions::sse3(), &dir).unwrap();
    let mut rng = XorShift64::new(9);
    let x = Tensor::rand(&[36, 18, 1], 0.0, 1.0, &mut rng);
    assert_eq!(cnn_a.infer(&x).unwrap(), cnn_b.infer(&x).unwrap());
}

/// The exported architecture JSON from Python must parse into the same
/// shapes as the Rust zoo (schema lock between the two sides).
#[test]
fn python_arch_json_matches_rust_zoo() {
    for name in zoo::PAPER_MODELS {
        let path = default_weights_dir().join(format!("{name}.json"));
        if !path.exists() {
            eprintln!("SKIP schema check {name}: run `make artifacts` first");
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let from_py = nncg::model::model_from_json(&text).unwrap();
        let from_zoo = zoo::by_name(name).unwrap().with_random_weights(1);
        assert_eq!(from_py.input, from_zoo.input, "{name}");
        assert_eq!(from_py.layers.len(), from_zoo.layers.len(), "{name}");
        assert_eq!(
            from_py.output_shape().unwrap(),
            from_zoo.output_shape().unwrap(),
            "{name}"
        );
    }
}

/// Failure injection: corrupt weights file, wrong shapes, bad JSON.
#[test]
fn corrupted_weight_files_are_rejected() {
    let dir = std::env::temp_dir().join("nncg-e2e-corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let model = zoo::ball_classifier().with_random_weights(3);
    nncg::model::save(&model, &dir.join("ball")).unwrap();

    // truncate the weights file
    let wpath = dir.join("ball.nncgw");
    let bytes = std::fs::read(&wpath).unwrap();
    std::fs::write(&wpath, &bytes[..bytes.len() / 2]).unwrap();
    assert!(nncg::model::load(&dir.join("ball")).is_err());

    // flip the magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&wpath, &bad).unwrap();
    assert!(nncg::model::load(&dir.join("ball")).is_err());

    // valid weights, corrupted architecture JSON
    std::fs::write(&wpath, &bytes).unwrap();
    std::fs::write(dir.join("ball.json"), "{not json").unwrap();
    assert!(nncg::model::load(&dir.join("ball")).is_err());
}

/// Coordinator must survive an engine that errors (oversized inputs) and
/// keep serving good requests afterwards.
#[test]
fn coordinator_recovers_from_bad_requests() {
    let engine = Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(2)).unwrap());
    let handle = coordinator::serve_single("tiny", engine, 1);
    assert!(handle.infer("tiny", Tensor::zeros(&[3, 3, 3])).is_err());
    assert!(handle.infer("tiny", Tensor::zeros(&[8, 8, 1])).is_ok());
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.total_requests, 2);
    assert_eq!(snap.errors, 1);
    handle.shutdown();
}

/// Trained-weights path: if `make train` has run, the ball classifier must
/// actually separate synthetic positives from negatives through the
/// *generated C* (the full train→export→codegen→deploy chain).
#[test]
fn trained_ball_classifier_separates_classes_through_generated_c() {
    let wdir = default_weights_dir();
    let log = wdir.join("train_log_ball.txt");
    if !log.exists() {
        eprintln!("SKIP trained-accuracy check: run `make train` first");
        return;
    }
    let model = load_model("ball", &wdir).unwrap();
    let cnn = CompiledCnn::build(&model, &CodegenOptions::sse3(), default_work_dir()).unwrap();
    let mut rng = XorShift64::new(1717);
    let (mut correct, n) = (0usize, 100usize);
    for i in 0..n {
        let positive = i % 2 == 0;
        let patch = render::ball_patch(positive, &mut rng);
        let probs = cnn.infer(&patch).unwrap();
        let pred_ball = probs.data()[1] > 0.5;
        if pred_ball == positive {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    // Rust renderer differs slightly from the python training distribution;
    // demand clearly-better-than-chance rather than the training accuracy.
    assert!(acc > 0.7, "generated-C accuracy {acc} on synthetic patches");
}
