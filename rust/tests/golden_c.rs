//! Golden-C snapshot tests: the generated C for each paper model under a
//! representative slice of the flag matrix (pad × tile × isa × fuse) is
//! checked in under `rust/tests/golden/`, so emitter refactors show up as
//! reviewable diffs instead of silent drift.
//!
//! Workflow:
//! * a missing snapshot is written on first run (and the test passes with
//!   a notice) — commit the new files;
//! * a mismatch fails with a summary; regenerate deliberately with
//!   `NNCG_BLESS=1 cargo test --test golden_c` and review the diff;
//! * every snapshot must stay inside the per-file statement budget — a
//!   config whose output blows past it fails even when blessed.
//!
//! Snapshot identity relies on `generate_c` being deterministic for a
//! fixed weight seed (asserted by `codegen_is_deterministic` in
//! `property_codegen.rs`).

use nncg::codegen::{generate_c, CodegenOptions, DType, FuseMode, Isa, PadMode, RolledMode, TileMode};
use nncg::graph::zoo;
use std::path::PathBuf;

/// Weight seed shared by every snapshot (arbitrary, but never change it —
/// that would invalidate all snapshots at once).
const SEED: u64 = 0x601D;

/// Hard per-snapshot budget: no checked-in configuration may exceed this
/// many C statements (the rolled fused robot, the largest, stays well
/// under; a regression that re-unrolls a steady state trips this).
const STMT_BUDGET: usize = 400_000;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

fn stmts(src: &str) -> usize {
    src.matches(';').count()
}

/// The snapshot matrix: (label, model, options). ~19 configurations
/// covering every ISA family, both pad modes, 1-D/2-D tiling, fusion in
/// both its rolled (robot/pedestrian stream periodically) and trivial
/// (ball is too short to roll) forms, and the `--dtype int8` emission
/// path (C89 baseline, SSE pair-madd, NEON dot-product, fused AVX2).
fn matrix() -> Vec<(&'static str, &'static str, CodegenOptions)> {
    vec![
        ("ball-default-sse3", "ball", CodegenOptions::sse3()),
        ("ball-paper-generic", "ball", CodegenOptions::paper_baseline(Isa::Generic)),
        ("ball-sse3-full-unroll", "ball", CodegenOptions::sse3_full_unroll()),
        ("ball-fused", "ball", CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() }),
        ("ball-neon", "ball", CodegenOptions { isa: Isa::Neon, ..Default::default() }),
        (
            "ball-avx2-tile2x4",
            "ball",
            CodegenOptions { isa: Isa::Avx2, tile: TileMode::Fixed2D(2, 4), ..Default::default() },
        ),
        ("pedestrian-default-sse3", "pedestrian", CodegenOptions::sse3()),
        (
            "pedestrian-fused-rolled",
            "pedestrian",
            CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() },
        ),
        (
            "pedestrian-padcopy-untiled",
            "pedestrian",
            CodegenOptions { pad_mode: PadMode::Copy, tile: TileMode::Off, ..CodegenOptions::sse3() },
        ),
        ("robot-default-sse3", "robot", CodegenOptions::sse3()),
        (
            "robot-fused-rolled",
            "robot",
            CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() },
        ),
        (
            "robot-neon-vfpv3-fused",
            "robot",
            CodegenOptions { isa: Isa::NeonVfpv3, fuse: FuseMode::Auto, ..Default::default() },
        ),
        // Rotated-mode snapshots: the explicit knob pins the
        // pointer-rotation emission even if the `auto` preference ever
        // changes; the expand snapshot pins its differential baseline.
        (
            "robot-avx2-fused-rotate",
            "robot",
            CodegenOptions {
                isa: Isa::Avx2,
                fuse: FuseMode::Auto,
                fuse_rolled: RolledMode::Rotate,
                ..Default::default()
            },
        ),
        (
            "pedestrian-fused-rotate",
            "pedestrian",
            CodegenOptions {
                fuse: FuseMode::Auto,
                fuse_rolled: RolledMode::Rotate,
                ..CodegenOptions::sse3()
            },
        ),
        (
            "pedestrian-fused-expand",
            "pedestrian",
            CodegenOptions {
                fuse: FuseMode::Auto,
                fuse_rolled: RolledMode::Expand,
                ..CodegenOptions::sse3()
            },
        ),
        // int8 snapshots (`--dtype int8`): the pure-C89 baseline, the
        // SSE madd_epi16 fused form, the vdotq_s32 packed-quad path, and
        // the widest-vector fused form with pinned pointer rotation.
        (
            "ball-int8-generic",
            "ball",
            CodegenOptions { isa: Isa::Generic, dtype: DType::Int8, ..Default::default() },
        ),
        (
            "ball-int8-sse3-fused",
            "ball",
            CodegenOptions { fuse: FuseMode::Auto, dtype: DType::Int8, ..CodegenOptions::sse3() },
        ),
        (
            "pedestrian-int8-neon-dot",
            "pedestrian",
            CodegenOptions { isa: Isa::NeonDot, dtype: DType::Int8, ..Default::default() },
        ),
        (
            "robot-int8-avx2-fused",
            "robot",
            CodegenOptions {
                isa: Isa::Avx2,
                fuse: FuseMode::Auto,
                fuse_rolled: RolledMode::Rotate,
                dtype: DType::Int8,
                ..Default::default()
            },
        ),
    ]
}

/// A short unified-diff-style hint around the first differing line, so a
/// drift failure is actionable without rerunning anything (the full new
/// output is also written next to the snapshot as `<label>.c.new`):
/// shared context lines print once with a leading space, then the two
/// diverging tails print as `-`/`+`, with an explicit end-of-file marker
/// when one output is a prefix of the other.
fn diff_hint(want: &str, got: &str) -> String {
    let w: Vec<&str> = want.lines().collect();
    let g: Vec<&str> = got.lines().collect();
    let first = w
        .iter()
        .zip(&g)
        .position(|(a, b)| a != b)
        .unwrap_or_else(|| w.len().min(g.len()));
    let mut out = format!("@@ first differing line {} @@\n", first + 1);
    for line in &w[first.saturating_sub(2)..first] {
        out.push_str(&format!(" {line}\n"));
    }
    for i in first..(first + 4).min(w.len()) {
        out.push_str(&format!("-{}\n", w[i]));
    }
    if first >= w.len() {
        out.push_str("-<end of snapshot>\n");
    }
    for i in first..(first + 4).min(g.len()) {
        out.push_str(&format!("+{}\n", g[i]));
    }
    if first >= g.len() {
        out.push_str("+<end of new output>\n");
    }
    out
}

#[test]
fn golden_snapshots_match() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let bless = std::env::var("NNCG_BLESS").map(|v| v == "1").unwrap_or(false);
    let mut blessed: Vec<String> = Vec::new();
    let mut drifted: Vec<String> = Vec::new();
    for (label, model, opts) in matrix() {
        let m = zoo::by_name(model).unwrap().with_random_weights(SEED);
        let src = generate_c(&m, &opts).unwrap_or_else(|e| panic!("{label}: {e:#}"));
        // Structural gates hold for every snapshot, blessed or not.
        assert_eq!(
            src.matches('{').count(),
            src.matches('}').count(),
            "{label}: unbalanced braces"
        );
        let n = stmts(&src);
        assert!(
            n <= STMT_BUDGET,
            "{label}: {n} statements exceed the {STMT_BUDGET} snapshot budget"
        );
        let path = dir.join(format!("{label}.c"));
        if bless || !path.exists() {
            std::fs::write(&path, &src).unwrap();
            blessed.push(label.to_string());
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        if want != src {
            // Write the new output next to the snapshot and show a small
            // unified diff, so the failure is reviewable immediately:
            //   diff -u rust/tests/golden/{label}.c rust/tests/golden/{label}.c.new
            let new_path = dir.join(format!("{label}.c.new"));
            std::fs::write(&new_path, &src).unwrap();
            drifted.push(format!(
                "{label}: {} -> {} bytes\n{}  (full output at {}; compare with `diff -u {} {}`)",
                want.len(),
                src.len(),
                diff_hint(&want, &src),
                new_path.display(),
                path.display(),
                new_path.display(),
            ));
        }
    }
    if !blessed.is_empty() {
        eprintln!(
            "golden_c: blessed {} snapshot(s): {} — commit rust/tests/golden/",
            blessed.len(),
            blessed.join(", ")
        );
    }
    assert!(
        drifted.is_empty(),
        "generated C drifted from the golden snapshots:\n  {}\nIf intentional, regenerate with \
         NNCG_BLESS=1 cargo test --test golden_c and review the diff.",
        drifted.join("\n  ")
    );
}

/// The snapshot labels are unique and every referenced model exists (a
/// cheap guard so a matrix edit cannot silently shadow a snapshot file).
#[test]
fn golden_matrix_is_well_formed() {
    let m = matrix();
    for (label, model, _) in &m {
        assert!(zoo::by_name(model).is_some(), "{label}: unknown model {model}");
    }
    let mut labels: Vec<&str> = m.iter().map(|(l, _, _)| *l).collect();
    labels.sort_unstable();
    labels.dedup();
    assert_eq!(labels.len(), m.len(), "duplicate snapshot labels");
    assert!(m.len() >= 19, "snapshot matrix must cover at least 19 configurations");
    // Every int8 snapshot must emit the quantized entry plane (cheap
    // structural guard that the dtype knob reached the emitter).
    for (label, model, opts) in &m {
        if opts.dtype != DType::Int8 {
            continue;
        }
        let model = zoo::by_name(model).unwrap().with_random_weights(SEED);
        let src = generate_c(&model, opts).unwrap();
        assert!(
            src.contains("signed char nncg_bufa"),
            "{label}: expected int8 ring buffers in emission"
        );
    }
    // The rolled-fusion configurations must actually roll — and the
    // explicit rotate/expand configurations must emit their form (guards
    // the matrix against a default change silently dropping coverage).
    for (label, model, opts) in &m {
        if !label.contains("fused-rolled") && !label.contains("-rotate") && !label.contains("-expand") {
            continue;
        }
        let model = zoo::by_name(model).unwrap().with_random_weights(SEED);
        let src = generate_c(&model, opts).unwrap();
        assert!(src.contains("/* steady state:"), "{label}: expected rolled emission");
        if label.contains("-rotate") {
            assert!(src.contains("rotated ring pointers"), "{label}: expected pointer rotation");
        }
        if label.contains("-expand") {
            assert!(src.contains("frozen ring slots"), "{label}: expected phase expansion");
        }
    }
}
