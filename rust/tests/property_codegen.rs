//! Property-based codegen testing (hand-rolled; proptest is not in the
//! offline crate set): generate random valid sequential CNNs, compile them
//! through the full pipeline (passes → codegen → cc → dlopen), and assert
//! the generated C agrees with the interpreter on random inputs.
//!
//! This explores architecture space far beyond the paper's three nets:
//! random kernel/stride/padding geometry, odd channel counts (SSE fallback
//! paths), BN in every legal position, dense heads, activation placement.

use nncg::codegen::{AlignMode, CodegenOptions, FuseMode, Isa, PadMode, RolledMode, TileMode, Unroll};
use nncg::graph::{Activation, Layer, Model, Padding};
use nncg::tensor::Tensor;
use nncg::util::XorShift64;

/// Build a random valid model. Dimensions kept small so the whole suite
/// stays fast (dozens of cc invocations).
fn random_model(rng: &mut XorShift64, seed_tag: usize) -> Model {
    let h = 6 + rng.below(8);
    let w = 6 + rng.below(8);
    let c = 1 + rng.below(3);
    let mut model = Model::new(&format!("fuzz{seed_tag}"), &[h, w, c]);
    let n_blocks = 1 + rng.below(3);
    let mut cur = (h, w);
    for b in 0..n_blocks {
        // conv
        let k = 1 + rng.below(3.min(cur.0).min(cur.1));
        let stride = 1 + rng.below(2);
        let c_out = 1 + rng.below(8);
        let padding = if rng.below(2) == 0 { Padding::Same } else { Padding::Valid };
        if padding == Padding::Valid && (k > cur.0 || k > cur.1) {
            continue;
        }
        model.layers.push(Layer::conv2d(c_out, k, k, (stride, stride), padding, Activation::None));
        cur = match padding {
            Padding::Same => ((cur.0 + stride - 1) / stride, (cur.1 + stride - 1) / stride),
            Padding::Valid => ((cur.0 - k) / stride + 1, (cur.1 - k) / stride + 1),
        };
        // optional BN (always legal right after conv)
        if rng.below(2) == 0 {
            model.layers.push(Layer::batchnorm(c_out));
        }
        // activation
        match rng.below(3) {
            0 => model.layers.push(Layer::relu()),
            1 => model.layers.push(Layer::leaky_relu(0.1)),
            _ => {}
        }
        // optional pool if it fits
        if b + 1 < n_blocks && cur.0 >= 2 && cur.1 >= 2 && rng.below(2) == 0 {
            model.layers.push(Layer::maxpool(2, 2));
            cur = ((cur.0 - 2) / 2 + 1, (cur.1 - 2) / 2 + 1);
        }
        if cur.0 < 2 || cur.1 < 2 {
            break;
        }
    }
    // optional dense head
    if rng.below(2) == 0 {
        model.layers.push(Layer::Flatten);
        model.layers.push(Layer::dense(2 + rng.below(6), Activation::None));
    }
    if rng.below(2) == 0 {
        model.layers.push(Layer::softmax());
    }
    model.with_random_weights(0xF00D + seed_tag as u64)
}

fn check(seed: u64, trials: usize) {
    let mut rng = XorShift64::new(seed);
    let work = std::env::temp_dir().join("nncg-fuzz");
    for t in 0..trials {
        let model = random_model(&mut rng, (seed as usize) * 100 + t);
        if model.validate().is_err() || model.infer_shapes().is_err() {
            continue; // generator produced a degenerate geometry; skip
        }
        let isa = if rng.below(2) == 0 { Isa::Generic } else { Isa::Sse3 };
        let unroll = match rng.below(4) {
            0 => Unroll::None,
            1 => Unroll::KeepOuter2,
            2 => Unroll::KeepOuter1,
            _ => Unroll::Full,
        };
        let pad_mode = match rng.below(3) {
            0 => PadMode::Auto,
            1 => PadMode::Copy,
            _ => PadMode::Padless,
        };
        let tile = match rng.below(4) {
            0 => TileMode::Auto,
            1 => TileMode::Off,
            2 => TileMode::Fixed(2 + rng.below(3)),
            _ => TileMode::Fixed2D(2 + rng.below(2), 2 + rng.below(3)),
        };
        let align = if rng.below(2) == 0 { AlignMode::Auto } else { AlignMode::Off };
        let fuse = match rng.below(3) {
            0 => FuseMode::Off,
            1 => FuseMode::Auto,
            _ => FuseMode::Depth(2 + rng.below(3)),
        };
        let opts = CodegenOptions { isa, unroll, pad_mode, tile, align, fuse, ..Default::default() };
        let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, seed + t as u64)
            .unwrap_or_else(|e| panic!("model {} opts {}: {e:#}", model.describe(), opts.tag()));
        assert!(
            err < 5e-4,
            "fuzz mismatch: err={err}\nopts={}\n{}",
            opts.tag(),
            model.describe()
        );
    }
}

#[test]
fn fuzz_codegen_batch_a() {
    check(1, 8);
}

#[test]
fn fuzz_codegen_batch_b() {
    check(2, 8);
}

#[test]
fn fuzz_codegen_batch_c() {
    check(3, 8);
}

/// Dense + flatten + SSE dense path specifically (the zoo has no dense
/// layer, so this guards the dense emitters).
#[test]
fn dense_head_through_all_unroll_levels() {
    let model = Model::new("densenet", &[6, 6, 2])
        .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::relu())
        .push(Layer::Flatten)
        .push(Layer::dense(8, Activation::None)) // SSE path (8 % 4 == 0)
        .push(Layer::relu())
        .push(Layer::dense(3, Activation::None)) // scalar fallback (3 % 4 != 0)
        .push(Layer::softmax())
        .with_random_weights(555);
    let work = std::env::temp_dir().join("nncg-fuzz-dense");
    for isa in [Isa::Generic, Isa::Sse3] {
        for unroll in [Unroll::None, Unroll::KeepOuter2, Unroll::Full] {
            let opts = CodegenOptions { isa, unroll, ..Default::default() };
            let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 9).unwrap();
            assert!(err < 1e-4, "{}: {err}", opts.tag());
        }
    }
}

/// Stride > kernel, asymmetric kernels, 1x1 convs — geometry edge cases.
#[test]
fn geometry_edge_cases() {
    let cases: Vec<Model> = vec![
        Model::new("one_by_one", &[5, 5, 3])
            .push(Layer::conv2d(4, 1, 1, (1, 1), Padding::Valid, Activation::None)),
        Model::new("wide_stride", &[9, 9, 1])
            .push(Layer::conv2d(4, 2, 2, (3, 3), Padding::Valid, Activation::None)),
        Model::new("asym_kernel", &[8, 6, 2])
            .push(Layer::conv2d(4, 4, 2, (1, 1), Padding::Valid, Activation::None)),
        Model::new("asym_stride_same", &[8, 8, 1])
            .push(Layer::conv2d(4, 3, 3, (2, 1), Padding::Same, Activation::None)),
        Model::new("pool_stride_1", &[6, 6, 4]).push(Layer::MaxPool2D { pool: (3, 3), stride: (1, 1) }),
        Model::new("full_extent_conv", &[4, 4, 2])
            .push(Layer::conv2d(2, 4, 4, (1, 1), Padding::Valid, Activation::None)),
    ];
    let work = std::env::temp_dir().join("nncg-fuzz-geom");
    for model in cases {
        let model = model.with_random_weights(77);
        for isa in [Isa::Generic, Isa::Sse3] {
            let opts = CodegenOptions { isa, unroll: Unroll::KeepOuter2, ..Default::default() };
            let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 3)
                .unwrap_or_else(|e| panic!("{}: {e:#}", model.name));
            assert!(err < 1e-4, "{} {isa:?}: {err}", model.name);
        }
    }
}

/// Fused emission is a pure schedule/buffer transformation: for random
/// models the compiled fused output must equal the unfused output **bit
/// for bit** (same taps, same order, same accumulators — only the row
/// schedule and the buffers between layers change).
#[test]
fn fuzz_fused_outputs_bit_identical() {
    let mut rng = XorShift64::new(0xFA5E);
    let work = std::env::temp_dir().join("nncg-fuzz-fused");
    // tiny_test_net is guaranteed to form a fusion group, and the
    // depthwise+avgpool chain covers the non-conv fused row emitters; the
    // random models stress odd geometries around them.
    let mut models = vec![
        nncg::graph::zoo::tiny_test_net().with_random_weights(71),
        Model::new("dwavg", &[8, 8, 4])
            .push(Layer::depthwise(3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::avgpool(2, 2))
            .push(Layer::conv2d(4, 1, 1, (1, 1), Padding::Valid, Activation::None))
            .with_random_weights(99),
    ];
    for t in 0..6usize {
        models.push(random_model(&mut rng, 9000 + t));
    }
    let mut fused_seen = 0;
    for model in &models {
        if model.validate().is_err() || model.infer_shapes().is_err() {
            continue;
        }
        let isa = if rng.below(2) == 0 { Isa::Generic } else { Isa::Sse3 };
        let base = CodegenOptions { isa, ..Default::default() };
        let fused_opts = CodegenOptions { fuse: FuseMode::Auto, ..base.clone() };
        let src = nncg::codegen::generate_c(model, &fused_opts).unwrap();
        if src.contains("nncg_ring") {
            fused_seen += 1;
        }
        let unfused = nncg::cc::CompiledCnn::build(model, &base, &work).unwrap();
        let fused = nncg::cc::CompiledCnn::from_source(model, &fused_opts, &src, &work).unwrap();
        for _ in 0..2 {
            let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
            assert_eq!(
                unfused.infer(&x).unwrap(),
                fused.infer(&x).unwrap(),
                "fused output differs:\n{}",
                model.describe()
            );
        }
    }
    assert!(fused_seen >= 1, "no model formed a fusion group");
}

/// Differential property (issue acceptance): **rotated** rolled fused,
/// **phase-expanded** rolled fused, unrolled fused, and unfused codegen
/// are four emissions of the same arithmetic — their compiled outputs
/// must be **bit-identical**. Covers odd channel counts, a stride-2 Same
/// conv and a pool inside the rolled group, a `phases = 15` chain the
/// old fuzz never reached (ring heights 5 and 3 at a 1-row advance), and
/// random chains across the fuse × pad × tile × isa surface (pad `copy`
/// degenerates to unfused emission and is covered by the plain fuzz).
#[test]
fn fuzz_rotated_vs_expanded_vs_unrolled_vs_unfused_bit_identical() {
    let mut rng = XorShift64::new(0x0110);
    let work = std::env::temp_dir().join("nncg-fuzz-rolled");
    // Deterministic chains known (schedule unit tests + simulation) to
    // settle into a rolled steady state.
    let mut models = vec![
        // odd channels + pool inside the group, 24 rows.
        Model::new("rollmix", &[24, 10, 3])
            .push(Layer::conv2d(6, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::None))
            .with_random_weights(31),
        // stride-2 Same conv feeding the chain, 32 rows.
        Model::new("rollstride", &[32, 9, 2])
            .push(Layer::conv2d(4, 3, 3, (2, 2), Padding::Same, Activation::None))
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .with_random_weights(32),
        // phases = lcm(5, 3) = 15: a 45-op expanded body vs a 3-op
        // rotated pattern — the regime phase expansion can't reach
        // cheaply and the previous fuzz never generated (kernels <= 3).
        Model::new("phases15", &[100, 6, 2])
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::conv2d(4, 5, 5, (1, 1), Padding::Same, Activation::None))
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None))
            .with_random_weights(33),
    ];
    for t in 0..5usize {
        models.push(random_model(&mut rng, 11000 + t));
    }
    let mut rotated_seen = 0usize;
    for (mi, model) in models.iter().enumerate() {
        if model.validate().is_err() || model.infer_shapes().is_err() {
            continue;
        }
        let isa = if rng.below(2) == 0 { Isa::Generic } else { Isa::Sse3 };
        let tile = match rng.below(3) {
            0 => TileMode::Auto,
            1 => TileMode::Off,
            _ => TileMode::Fixed(2 + rng.below(3)),
        };
        let base = CodegenOptions { isa, tile, pad_mode: PadMode::Auto, ..Default::default() };
        let variant = |mode: RolledMode| CodegenOptions {
            fuse: FuseMode::Auto,
            fuse_rolled: mode,
            ..base.clone()
        };
        let rotated_src = nncg::codegen::generate_c(model, &variant(RolledMode::Rotate)).unwrap();
        let expanded_src = nncg::codegen::generate_c(model, &variant(RolledMode::Expand)).unwrap();
        let unrolled_src = nncg::codegen::generate_c(model, &variant(RolledMode::Off)).unwrap();
        let auto_src = nncg::codegen::generate_c(model, &variant(RolledMode::Auto)).unwrap();
        if rotated_src.contains("rotated ring pointers") {
            rotated_seen += 1;
            assert!(
                rotated_src.len() < unrolled_src.len(),
                "{}: rotation must shrink the generated C",
                model.name
            );
        }
        if mi < 3 {
            assert!(
                rotated_src.contains("rotated ring pointers"),
                "{}: deterministic chain must rotate",
                model.name
            );
            assert_eq!(auto_src, rotated_src, "{}: auto must prefer rotation", model.name);
        }
        if mi == 2 {
            // The phases-15 chain must also keep an expanded form (15
            // phases is still under the 64-phase cap) so the three-way
            // comparison is non-degenerate.
            assert!(expanded_src.contains("frozen ring slots"), "phases15 must phase-expand");
            assert!(
                rotated_src.len() * 2 < expanded_src.len(),
                "phases15: the 45-op expanded body must dwarf the rotated pattern"
            );
        }
        let unfused = nncg::cc::CompiledCnn::build(model, &base, &work).unwrap();
        let compiled = [
            ("rotated", &rotated_src, variant(RolledMode::Rotate)),
            ("expanded", &expanded_src, variant(RolledMode::Expand)),
            ("unrolled", &unrolled_src, variant(RolledMode::Off)),
        ]
        .map(|(label, src, opts)| {
            (label, nncg::cc::CompiledCnn::from_source(model, &opts, src, &work).unwrap())
        });
        for _ in 0..2 {
            let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
            let y0 = unfused.infer(&x).unwrap();
            for (label, cnn) in &compiled {
                assert_eq!(
                    y0,
                    cnn.infer(&x).unwrap(),
                    "{}: {label} fused output differs from unfused",
                    model.name
                );
            }
        }
    }
    assert!(rotated_seen >= 3, "only {rotated_seen} models exercised the rotated path");
}

/// int8 differential fuzz (issue acceptance): for random quantizable
/// models across the fuse × rolled × pad × tile × chan-pad surface,
/// every emission form of the same quant plan must produce
/// **bit-identical** compiled output (the integer chain is
/// saturation-free, so no form has accumulation-order freedom), and
/// that output must match the int8 interpreter oracle to within the
/// float softmax epilogue's libm term.
#[test]
fn fuzz_int8_forms_bit_identical_and_match_oracle() {
    use nncg::codegen::{ChanPad, DType};
    use nncg::interp::run_quantized;
    use nncg::passes::{optimize, quantize_model};
    let mut rng = XorShift64::new(0x1D8);
    let work = std::env::temp_dir().join("nncg-fuzz-int8");
    let mut models = vec![nncg::graph::zoo::tiny_test_net().with_random_weights(81)];
    for t in 0..6usize {
        models.push(random_model(&mut rng, 13000 + t));
    }
    let mut quantized_seen = 0usize;
    for model in &models {
        if model.validate().is_err() || model.infer_shapes().is_err() {
            continue;
        }
        // Derive the same optimized model + quant plan codegen will use;
        // skip structures the quantizer rejects (it bails rather than
        // silently degrading).
        let opt = match optimize(model.clone()) {
            Ok(m) => m,
            Err(_) => continue,
        };
        let qp = match quantize_model(&opt) {
            Ok(p) => p,
            Err(_) => continue,
        };
        quantized_seen += 1;
        let isa = if rng.below(2) == 0 { Isa::Generic } else { Isa::Sse3 };
        let unroll = if rng.below(2) == 0 { Unroll::KeepOuter2 } else { Unroll::KeepOuter1 };
        let pad_mode = if rng.below(2) == 0 { PadMode::Auto } else { PadMode::Copy };
        let tile = match rng.below(3) {
            0 => TileMode::Auto,
            1 => TileMode::Off,
            _ => TileMode::Fixed(2 + rng.below(3)),
        };
        let chan_pad = if rng.below(2) == 0 { ChanPad::Auto } else { ChanPad::Off };
        let base = CodegenOptions {
            isa,
            unroll,
            pad_mode,
            tile,
            chan_pad,
            dtype: DType::Int8,
            ..Default::default()
        };
        let variants = [
            CodegenOptions { fuse: FuseMode::Off, ..base.clone() },
            CodegenOptions { fuse: FuseMode::Auto, fuse_rolled: RolledMode::Rotate, ..base.clone() },
            CodegenOptions { fuse: FuseMode::Auto, fuse_rolled: RolledMode::Expand, ..base.clone() },
            CodegenOptions { fuse: FuseMode::Auto, fuse_rolled: RolledMode::Off, ..base.clone() },
        ];
        let cnns: Vec<_> = variants
            .iter()
            .map(|opts| {
                nncg::cc::CompiledCnn::build(model, opts, &work)
                    .unwrap_or_else(|e| panic!("{} {}: {e:#}", model.name, opts.tag()))
            })
            .collect();
        for _ in 0..2 {
            let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
            let y_oracle = run_quantized(&opt, &qp, &x).unwrap();
            let y0 = cnns[0].infer(&x).unwrap();
            let err = y_oracle.max_abs_diff(&y0).unwrap();
            assert!(
                err < 1e-6,
                "{}: int8 C deviates from oracle by {err}\n{}",
                model.name,
                model.describe()
            );
            for (cnn, opts) in cnns.iter().zip(&variants).skip(1) {
                assert_eq!(
                    y0,
                    cnn.infer(&x).unwrap(),
                    "{} {}: int8 forms must be bit-identical",
                    model.name,
                    opts.tag()
                );
            }
        }
    }
    assert!(quantized_seen >= 3, "only {quantized_seen} fuzz models were quantizable");
}

/// Same seed ⇒ byte-identical generated C (reproducible builds).
#[test]
fn codegen_is_deterministic() {
    let m1 = nncg::graph::zoo::ball_classifier().with_random_weights(42);
    let m2 = nncg::graph::zoo::ball_classifier().with_random_weights(42);
    let opts = CodegenOptions::sse3();
    let a = nncg::codegen::generate_c(&m1, &opts).unwrap();
    let b = nncg::codegen::generate_c(&m2, &opts).unwrap();
    assert_eq!(a, b);
}

/// Inputs with extreme values must not produce NaN/Inf through any engine.
#[test]
fn extreme_inputs_stay_finite() {
    let model = nncg::graph::zoo::ball_classifier().with_random_weights(10);
    let work = std::env::temp_dir().join("nncg-fuzz-extreme");
    let cnn = nncg::cc::CompiledCnn::build(&model, &CodegenOptions::sse3(), &work).unwrap();
    for fill in [0.0f32, 1.0, -1.0, 1e4, -1e4] {
        let x = Tensor::from_vec(&[16, 16, 1], vec![fill; 256]).unwrap();
        let y = cnn.infer(&x).unwrap();
        assert!(y.data().iter().all(|v| v.is_finite()), "fill={fill}: {:?}", y.data());
    }
}

/// AVX2 backend (paper future work): correctness across the paper models.
/// Skips when the host CPU lacks AVX2 (the generated intrinsics would not
/// compile/run with -march=native).
#[test]
fn avx2_backend_matches_interp() {
    if !std::arch::is_x86_feature_detected!("avx2") || !std::arch::is_x86_feature_detected!("fma") {
        eprintln!("SKIP avx2 test: host lacks AVX2/FMA");
        return;
    }
    let work = std::env::temp_dir().join("nncg-fuzz-avx2");
    for name in ["ball", "pedestrian", "robot"] {
        let model = nncg::graph::zoo::by_name(name).unwrap().with_random_weights(31);
        for unroll in [Unroll::None, Unroll::KeepOuter2] {
            let opts = CodegenOptions { isa: Isa::Avx2, unroll, ..Default::default() };
            let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 17).unwrap();
            assert!(err < 5e-4, "{name} {}: {err}", opts.tag());
        }
    }
}

/// AVX2 remainder lanes: odd channel counts must keep 8-wide groups where
/// they fit, drop to SSE for the 4-lane remainder, and finish scalar —
/// and still match the interpreter. Skips when the host lacks AVX2.
#[test]
fn avx2_remainder_lanes_match_interp() {
    if !std::arch::is_x86_feature_detected!("avx2") || !std::arch::is_x86_feature_detected!("fma") {
        eprintln!("SKIP avx2 remainder test: host lacks AVX2/FMA");
        return;
    }
    let model = Model::new("avx2odd", &[8, 8, 2])
        .push(Layer::conv2d(13, 3, 3, (1, 1), Padding::Same, Activation::Relu))
        .push(Layer::conv2d(6, 3, 3, (2, 2), Padding::Same, Activation::None))
        .push(Layer::softmax())
        .with_random_weights(909);
    let work = std::env::temp_dir().join("nncg-fuzz-avx2-odd");
    for tile in [TileMode::Off, TileMode::Auto] {
        let opts = CodegenOptions { isa: Isa::Avx2, tile, ..Default::default() };
        let src = nncg::codegen::generate_c(&model, &opts).unwrap();
        // c_out=13 → one 8-wide group, one 4-wide group, one scalar lane.
        assert!(src.contains("_mm256_"), "{}: expected 8-wide groups", opts.tag());
        assert!(src.contains("_mm_"), "{}: expected a 4-wide remainder group", opts.tag());
        let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 31).unwrap();
        assert!(err < 5e-4, "{}: {err}", opts.tag());
    }
}

/// Padless emission is byte-stable and never references the pad buffer,
/// for both conv and depthwise layers.
#[test]
fn padless_depthwise_matches_interp_and_drops_pad_buffer() {
    let model = Model::new("dwpadless", &[10, 9, 6])
        .push(Layer::depthwise(3, 3, (2, 2), Padding::Same, Activation::Relu))
        .push(Layer::conv2d(5, 1, 1, (1, 1), Padding::Valid, Activation::None))
        .push(Layer::softmax())
        .with_random_weights(77);
    let work = std::env::temp_dir().join("nncg-fuzz-dw-padless");
    for isa in [Isa::Generic, Isa::Sse3] {
        for unroll in [Unroll::KeepOuter2, Unroll::Full] {
            let opts = CodegenOptions { isa, unroll, pad_mode: PadMode::Padless, ..Default::default() };
            let src = nncg::codegen::generate_c(&model, &opts).unwrap();
            assert!(!src.contains("nncg_pad"), "{}", opts.tag());
            let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 41).unwrap();
            assert!(err < 1e-4, "{}: {err}", opts.tag());
        }
    }
}

/// MobileNet-style depthwise-separable net (paper future work: depthwise,
/// avgpool, 1x1 convs) through every ISA + the interpreter — including the
/// paper's MobileNetV2 size anecdote: generated C size is reported and the
/// file still compiles and runs correctly.
#[test]
fn mobilenet_mini_all_isas_match_interp() {
    let model = nncg::graph::zoo::mobilenet_mini().with_random_weights(2024);
    let work = std::env::temp_dir().join("nncg-fuzz-mobilenet");
    for isa in [Isa::Generic, Isa::Sse3, Isa::Avx2] {
        if isa == Isa::Avx2 && !std::arch::is_x86_feature_detected!("avx2") {
            continue;
        }
        let opts = CodegenOptions { isa, unroll: Unroll::KeepOuter2, ..Default::default() };
        let src = nncg::codegen::generate_c(&model, &opts).unwrap();
        assert!(src.len() > 10_000, "suspiciously small C for {}", opts.tag());
        let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 5).unwrap();
        assert!(err < 5e-4, "{}: {err}", opts.tag());
    }
}

/// Depthwise + avgpool also survive the loop-form (Unroll::None) emission.
#[test]
fn mobilenet_mini_loop_form() {
    let model = nncg::graph::zoo::mobilenet_mini().with_random_weights(7);
    let opts = CodegenOptions { isa: Isa::Sse3, unroll: Unroll::None, ..Default::default() };
    let work = std::env::temp_dir().join("nncg-fuzz-mobilenet");
    let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 6).unwrap();
    assert!(err < 5e-4, "{err}");
}
