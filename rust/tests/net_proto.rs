//! Protocol torture tests for the TCP front-end's wire format.
//!
//! Two layers:
//!
//! 1. **Codec**: seeded random frames round-trip through encode→decode
//!    under adversarial segmentation — 1-byte reads, random split points
//!    (length prefixes cut mid-field), and coalesced frames (many frames
//!    in one contiguous buffer). Malformed inputs (bad magic, version
//!    skew, oversize lengths, truncated payloads) each yield a *typed*
//!    [`FrameError`] — never a panic, never a hang, never an allocation
//!    driven by an unvalidated length.
//! 2. **Server**: each malformed byte pattern sent to a live [`NetServer`]
//!    closes that connection (observed as EOF client-side) while the
//!    server itself stays up and serves a fresh connection — and bumps
//!    `net_bad_frames` instead of crashing.
//!
//! Seeded via `NNCG_CHAOS_SEED` (CI runs 1, 2, 3).

use nncg::coordinator::proto::{
    self, encode_err, encode_ok, encode_request, read_request, read_response, status_name,
    status_of, FrameError, ResponseBody, MAGIC, MAX_DIMS, MAX_ELEMS, MAX_MODEL_LEN, VERSION,
};
use nncg::coordinator::{serve_sharded, NetClient, NetConfig, NetServer, Router, ServeError, ShardConfig};
use nncg::graph::zoo;
use nncg::interp::InterpEngine;
use nncg::tensor::Tensor;
use nncg::util::XorShift64;
use std::io::Read;
use std::sync::Arc;

fn chaos_seed() -> u64 {
    std::env::var("NNCG_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// A reader that serves an in-memory buffer in adversarially small
/// chunks: `max_chunk == 1` is the pure 1-byte-read case; larger values
/// split the stream at seeded random points, so length prefixes and f32
/// payloads land across read boundaries.
struct ChunkedReader {
    buf: Vec<u8>,
    pos: usize,
    rng: XorShift64,
    max_chunk: usize,
}

impl ChunkedReader {
    fn new(buf: Vec<u8>, seed: u64, max_chunk: usize) -> Self {
        ChunkedReader { buf, pos: 0, rng: XorShift64::new(seed), max_chunk: max_chunk.max(1) }
    }
}

impl Read for ChunkedReader {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.buf.len() {
            return Ok(0);
        }
        let chunk = 1 + self.rng.below(self.max_chunk);
        let n = chunk.min(out.len()).min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

fn random_request(rng: &mut XorShift64, id: u64) -> (String, Vec<usize>, Vec<f32>) {
    let name_len = 1 + rng.below(24);
    let model: String =
        (0..name_len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
    let ndims = 1 + rng.below(3);
    let dims: Vec<usize> = (0..ndims).map(|_| 1 + rng.below(6)).collect();
    let count: usize = dims.iter().product();
    let data: Vec<f32> = (0..count).map(|_| rng.uniform(-100.0, 100.0) + id as f32).collect();
    (model, dims, data)
}

/// Round-trip seeded random request frames through every segmentation
/// regime: whole-buffer, 1-byte reads, and random chunking.
#[test]
fn request_frames_round_trip_under_adversarial_segmentation() {
    let seed = chaos_seed();
    let mut rng = XorShift64::new(seed ^ 0xA11CE);
    for i in 0..200u64 {
        let (model, dims, data) = random_request(&mut rng, i);
        let buf = encode_request(i, &model, &dims, &data).expect("encodable");
        for max_chunk in [1usize, 3, 7, buf.len()] {
            let mut r = ChunkedReader::new(buf.clone(), seed.wrapping_add(i), max_chunk);
            let frame = read_request(&mut r)
                .unwrap_or_else(|e| panic!("decode failed (chunk {max_chunk}): {e}"))
                .expect("one frame present");
            assert_eq!(frame.id, i);
            assert_eq!(frame.model, model);
            assert_eq!(frame.dims, dims);
            assert_eq!(frame.data, data, "f32 payload must be bit-identical");
        }
    }
}

/// Coalesced frames: many frames packed into one buffer decode back in
/// order, under 1-byte reads, with a clean EOF after the last.
#[test]
fn coalesced_frames_decode_in_order() {
    let seed = chaos_seed();
    let mut rng = XorShift64::new(seed ^ 0xC0A1E5CE);
    let mut buf = Vec::new();
    let mut expected = Vec::new();
    for i in 0..32u64 {
        let (model, dims, data) = random_request(&mut rng, i);
        buf.extend_from_slice(&encode_request(i, &model, &dims, &data).unwrap());
        expected.push((model, dims, data));
    }
    let mut r = ChunkedReader::new(buf, seed, 1);
    for (i, (model, dims, data)) in expected.iter().enumerate() {
        let frame = read_request(&mut r).unwrap().expect("frame present");
        assert_eq!(frame.id, i as u64);
        assert_eq!(&frame.model, model);
        assert_eq!(&frame.dims, dims);
        assert_eq!(&frame.data, data);
    }
    assert!(read_request(&mut r).unwrap().is_none(), "clean EOF at the frame boundary");
}

/// Response frames (success and every error status) round-trip under
/// random segmentation.
#[test]
fn response_frames_round_trip_under_segmentation() {
    let seed = chaos_seed();
    let mut rng = XorShift64::new(seed ^ 0x5E5F);
    for i in 0..100u64 {
        let dims = vec![1 + rng.below(4) as usize, 1 + rng.below(4) as usize];
        let count: usize = dims.iter().product();
        let data: Vec<f32> = (0..count).map(|_| rng.normal()).collect();
        let t = Tensor::from_vec(&dims, data.clone()).unwrap();
        let buf = encode_ok(i, &t).unwrap();
        let mut r = ChunkedReader::new(buf, seed ^ i, 1 + (i % 5) as usize);
        let f = read_response(&mut r).unwrap().expect("frame");
        assert_eq!(f.id, i);
        assert_eq!(f.status, proto::STATUS_OK);
        assert_eq!(f.body, ResponseBody::Tensor { dims: dims.clone(), data });
    }
    let errors = [
        ServeError::DeadlineExceeded { model: "m".into(), late_by_us: 12 },
        ServeError::QueueFull { capacity: 9 },
        ServeError::EngineFailed { model: "m".into(), reason: "boom".into() },
        ServeError::ModelUnknown { model: "m".into(), registered: vec!["ball".into()] },
        ServeError::Degraded {
            model: "m".into(),
            primary_error: "p".into(),
            fallback_error: "f".into(),
        },
        ServeError::Stopped,
    ];
    for (i, e) in errors.iter().enumerate() {
        let buf = encode_err(i as u64, e);
        let mut r = ChunkedReader::new(buf, seed ^ (i as u64) << 3, 2);
        let f = read_response(&mut r).unwrap().expect("frame");
        assert_eq!(f.id, i as u64);
        assert_eq!(f.status, status_of(e));
        assert_eq!(status_name(f.status), Some(e.kind()), "status byte ↔ kind mapping");
        match &f.body {
            ResponseBody::Message(m) => assert_eq!(m, &e.to_string()),
            other => panic!("expected message body, got {other:?}"),
        }
    }
}

/// Every malformed-input class maps to its typed error. Never a panic;
/// oversize length prefixes are rejected *before* any allocation.
#[test]
fn malformed_inputs_yield_typed_errors() {
    let good = encode_request(1, "ball", &[2, 2], &[0.0; 4]).unwrap();

    // Bad magic.
    let mut bad = good.clone();
    bad[0] = b'X';
    assert!(matches!(
        read_request(&mut bad.as_slice()).unwrap_err(),
        FrameError::BadMagic(_)
    ));

    // Version skew.
    let mut bad = good.clone();
    bad[4] = VERSION + 1;
    assert_eq!(
        read_request(&mut bad.as_slice()).unwrap_err(),
        FrameError::BadVersion { got: VERSION + 1 }
    );

    // Oversize model-name length.
    let mut bad = good.clone();
    bad[13..15].copy_from_slice(&(MAX_MODEL_LEN as u16 + 1).to_le_bytes());
    assert_eq!(
        read_request(&mut bad.as_slice()).unwrap_err(),
        FrameError::ModelTooLong { len: MAX_MODEL_LEN + 1 }
    );

    // Oversize dims product (a hostile length prefix claiming 2^32-1 per
    // dim) must be rejected without allocating the claimed payload.
    let mut bad = Vec::new();
    bad.extend_from_slice(&MAGIC);
    bad.push(VERSION);
    bad.extend_from_slice(&7u64.to_le_bytes());
    bad.extend_from_slice(&1u16.to_le_bytes());
    bad.push(b'm');
    bad.push(2); // ndims
    bad.extend_from_slice(&u32::MAX.to_le_bytes());
    bad.extend_from_slice(&u32::MAX.to_le_bytes());
    bad.extend_from_slice(&u32::MAX.to_le_bytes()); // count
    assert!(matches!(
        read_request(&mut bad.as_slice()).unwrap_err(),
        FrameError::Oversize { elems } if elems > MAX_ELEMS
    ));

    // Zero and oversize rank.
    for ndims in [0u8, MAX_DIMS as u8 + 1] {
        let mut bad = Vec::new();
        bad.extend_from_slice(&MAGIC);
        bad.push(VERSION);
        bad.extend_from_slice(&7u64.to_le_bytes());
        bad.extend_from_slice(&1u16.to_le_bytes());
        bad.push(b'm');
        bad.push(ndims);
        assert_eq!(
            read_request(&mut bad.as_slice()).unwrap_err(),
            FrameError::BadDims { ndims: ndims as usize }
        );
    }

    // Count disagreeing with the dims product.
    let mut bad = good.clone();
    let count_off = good.len() - 4 * 4 - 4;
    bad[count_off..count_off + 4].copy_from_slice(&3u32.to_le_bytes());
    assert_eq!(
        read_request(&mut bad.as_slice()).unwrap_err(),
        FrameError::CountMismatch { count: 3, product: 4 }
    );

    // Non-UTF-8 model name.
    let mut bad = good.clone();
    bad[15] = 0xFF;
    assert_eq!(read_request(&mut bad.as_slice()).unwrap_err(), FrameError::BadUtf8);

    // Truncation at every prefix length of a valid frame.
    for cut in 1..good.len() {
        assert_eq!(
            read_request(&mut good[..cut].to_vec().as_slice()).unwrap_err(),
            FrameError::Truncated,
            "cut at {cut}"
        );
    }

    // Unknown response status byte.
    let t = Tensor::from_vec(&[1], vec![1.0]).unwrap();
    let mut bad = encode_ok(3, &t).unwrap();
    bad[13] = 250;
    assert_eq!(
        read_response(&mut bad.as_slice()).unwrap_err(),
        FrameError::BadStatus { got: 250 }
    );
}

/// Seeded fuzz: random corruptions of valid frames either decode to the
/// original (corruption hit the f32 payload, which has no invalid bit
/// patterns the framing cares about) or fail with a typed error — never a
/// panic. This is the "test suite as spec" backstop for the whole decode
/// surface.
#[test]
fn random_corruptions_never_panic() {
    let seed = chaos_seed();
    let mut rng = XorShift64::new(seed ^ 0xF022);
    for i in 0..500u64 {
        let (model, dims, data) = random_request(&mut rng, i);
        let mut buf = encode_request(i, &model, &dims, &data).unwrap();
        // Corrupt 1-4 random bytes (or truncate).
        if rng.below(4) == 0 {
            let keep = rng.below(buf.len());
            buf.truncate(keep);
        } else {
            for _ in 0..=rng.below(4) {
                let at = rng.below(buf.len());
                buf[at] ^= 1u8 << rng.below(8);
            }
        }
        // Must return, Ok or typed Err — the decode cannot panic or hang.
        let _ = read_request(&mut buf.as_slice());
    }
}

fn tiny_pool() -> (nncg::coordinator::ServerHandle, Arc<Router>) {
    let router = Arc::new(Router::new());
    router.register(
        "tiny",
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap()),
    );
    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig { shards: 1, workers_per_shard: 1, ..ShardConfig::default() },
    );
    (handle, router)
}

/// Server-level contract: every malformed byte pattern closes *that*
/// connection (EOF client-side, no reply frame) and bumps
/// `net_bad_frames`; the server keeps serving fresh connections.
#[test]
fn malformed_frames_close_the_connection_but_not_the_server() {
    let (handle, _router) = tiny_pool();
    let server =
        NetServer::start(handle.submitter(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = server.local_addr();

    let good = encode_request(1, "tiny", &[8, 8, 1], &[0.25; 64]).unwrap();
    let mut bad_magic = good.clone();
    bad_magic[0] = b'X';
    let mut bad_version = good.clone();
    bad_version[4] = VERSION + 9;
    let mut oversize = good.clone();
    oversize[13..15].copy_from_slice(&u16::MAX.to_le_bytes());
    let malformed: Vec<Vec<u8>> = vec![bad_magic, bad_version, oversize];

    for bytes in &malformed {
        let mut client = NetClient::connect(addr).expect("connect");
        client.send_raw(bytes).expect("raw write");
        // The server must close the connection without replying: the next
        // read sees EOF (Closed), not a frame and not a hang.
        match client.read_reply() {
            Err(_) => {}
            Ok(reply) => panic!("malformed frame must not be answered, got {reply:?}"),
        }
    }

    // The server survives: a fresh connection still serves inference.
    let mut client = NetClient::connect(addr).expect("connect after abuse");
    let x = Tensor::from_vec(&[8, 8, 1], vec![0.25; 64]).unwrap();
    let y = client.infer("tiny", &x).expect("server still serving");
    assert_eq!(y.dims(), &[2, 2, 2]);

    server.stop();
    let snap = handle.stop();
    assert_eq!(snap.net_bad_frames, malformed.len() as u64);
    assert_eq!(snap.net_connections, malformed.len() as u64 + 1);
    // Malformed frames are never accepted, so frames == replies == 1 (the
    // one good inference).
    assert_eq!(snap.net_frames, 1);
    assert_eq!(snap.net_replies, 1);
}

/// A truncated payload (client hangs up mid-frame) is a dropped
/// connection, not a bad frame, and gets no reply.
#[test]
fn truncated_frame_is_a_dropped_connection() {
    let (handle, _router) = tiny_pool();
    let server =
        NetServer::start(handle.submitter(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let good = encode_request(1, "tiny", &[8, 8, 1], &[0.5; 64]).unwrap();

    let client = {
        let mut c = NetClient::connect(server.local_addr()).unwrap();
        c.send_raw(&good[..good.len() / 2]).unwrap();
        c.shutdown(std::net::Shutdown::Write).unwrap();
        c
    };
    drop(client); // full close; server sees EOF mid-frame

    // Serve one good request afterwards to sequence the assertion after
    // the server has certainly processed the truncated connection.
    let mut c2 = NetClient::connect(server.local_addr()).unwrap();
    let x = Tensor::from_vec(&[8, 8, 1], vec![0.5; 64]).unwrap();
    c2.infer("tiny", &x).expect("still serving");

    server.stop();
    let snap = handle.stop();
    assert_eq!(snap.net_dropped_conns, 1, "mid-frame EOF is a dropped conn");
    assert_eq!(snap.net_bad_frames, 0);
    assert_eq!(snap.net_frames, 1, "the truncated frame was never accepted");
}
