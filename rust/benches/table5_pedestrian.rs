//! Reproduces paper Table V: execution time of the pedestrian classifier.

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("NNCG_BENCH_QUICK").is_ok();
    let result = nncg::experiments::run_table5(quick)?;
    println!("{}", result.rendered);
    Ok(())
}
