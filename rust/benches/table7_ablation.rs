//! Reproduces paper Table VII: the feature ablation (general ISA vs SSSE3
//! vs SSSE3 + full unroll) on the ball classifier, plus the pad/tile
//! ablation (pad-copy vs padless × untiled vs tiled) over every paper
//! model — written to `BENCH_table7.json` (override the path with
//! `NNCG_BENCH_JSON`) so future sessions can track the perf trajectory —
//! plus an extended sweep over every (ISA × unroll × const-mode)
//! combination.

use nncg::bench_harness::{bench, BenchConfig, Table};
use nncg::cc::CompiledCnn;
use nncg::codegen::{CodegenOptions, ConstMode, Isa, Unroll};
use nncg::experiments::{default_weights_dir, default_work_dir, load_model};
use nncg::tensor::Tensor;
use nncg::util::{fmt_us, XorShift64};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("NNCG_BENCH_QUICK").is_ok();
    // The paper's three-column table (+ padless/tiled rows).
    let result = nncg::experiments::run_table7(quick)?;
    println!("{}", result.rendered);

    // Pad/tile ablation over all paper models → BENCH_table7.json.
    let rows = nncg::experiments::run_pad_tile_ablation(quick)?;
    println!("{}", nncg::experiments::render_ablation(&rows));
    let json_path = std::env::var("NNCG_BENCH_JSON").unwrap_or_else(|_| "BENCH_table7.json".to_string());
    nncg::experiments::write_bench_json(std::path::Path::new(&json_path), &rows, "measured")?;
    println!("wrote {json_path} ({} rows)\n", rows.len());

    // Extended ablation: full option matrix on the ball classifier.
    let model = load_model("ball", &default_weights_dir())?;
    let mut rng = XorShift64::new(7);
    let input = Tensor::rand(model.input.dims(), 0.0, 1.0, &mut rng);
    let mut out = vec![0.0f32; model.output_shape()?.numel()];
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::small() };

    let mut t = Table::new(
        "EXTENDED ABLATION: ball classifier, all codegen option combinations",
        &["isa", "unroll", "constants", "median", "C size"],
    );
    for isa in [Isa::Generic, Isa::Sse3, Isa::Avx2] {
        for unroll in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1, Unroll::Full] {
            let const_modes: &[Option<ConstMode>] = if unroll == Unroll::None {
                &[Some(ConstMode::Array)]
            } else {
                &[Some(ConstMode::Inline), Some(ConstMode::Array)]
            };
            for &const_mode in const_modes {
                let opts = CodegenOptions { isa, unroll, const_mode, ..Default::default() };
                let src = nncg::codegen::generate_c(&model, &opts)?;
                let cnn = CompiledCnn::from_source(&model, &opts, &src, default_work_dir())?;
                let stats = bench(&cfg, || cnn.infer_into(input.data(), &mut out));
                t.row(vec![
                    format!("{isa:?}"),
                    unroll.name().into(),
                    format!("{:?}", opts.effective_const_mode()),
                    fmt_us(stats.median_us),
                    format!("{}K", src.len() / 1024),
                ]);
            }
        }
    }
    println!("{}", t.render());
    Ok(())
}
