//! Million-request load + chaos benchmark for the sharded coordinator.
//!
//! Drives `NNCG_LOAD_REQUESTS` (default 1 000 000) requests across the
//! three paper models — ball ~90%, pedestrian ~8%, robot ~2% — from
//! `NNCG_LOAD_CLIENTS` submitter threads with a bounded in-flight window
//! each, against a `NNCG_LOAD_SHARDS`-shard pool with work stealing on.
//! Engines are the real generated-C builds when the host has a C
//! compiler, interpreter engines otherwise.
//!
//! While the load runs, a chaos driver (disable with
//! `NNCG_LOAD_CHAOS=off`) injects seeded shard kills and steal-race
//! delays via `FaultPlan`, recycles shards under live traffic, and runs
//! background heal rebuilds through the `HealPipeline`.
//!
//! `NNCG_LOAD_TCP=1` puts the length-prefixed TCP front-end (`NetServer`)
//! on loopback and drives every request through a per-client `NetClient`
//! instead of the in-process `Submitter` — same accounting gate, with
//! remote queue-full replies counted as sheds. `NNCG_SERVE_STEAL_POLICY`
//! (half-length|one-length|half-age|one-age) picks the steal policy; the
//! policy and its realized `steals` count land in the JSON.
//!
//! The benchmark **gates** on exactly-one-reply accounting —
//! `submitted == replied_ok + replied_err + shed` and `lost == 0` — and
//! exits non-zero on any violation (CI runs a 10⁴-request smoke with the
//! gate only; perf numbers are informational). Results are written to
//! `BENCH_serving.json`: sustained req/s plus client-side p50/p99/p999.

use nncg::cc::{CcDriver, CompiledCnn};
use nncg::codegen::CodegenOptions;
use nncg::coordinator::{
    home_shard, serve_sharded, BatcherPolicy, BreakerConfig, HealPipeline, LatencyHisto, NetClient,
    NetConfig, NetServer, Router, ServeError, ShardConfig, StealPolicy,
};
use nncg::faults::{FaultPlan, FaultSite, FaultSpec};
use nncg::graph::zoo;
use nncg::interp::InterpEngine;
use nncg::model::json::Value;
use nncg::runtime::InferenceEngine;
use nncg::tensor::Tensor;
use nncg::util::XorShift64;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok()).unwrap_or(default)
}

/// Per-client accounting; summed into the global gate.
#[derive(Default)]
struct ClientTally {
    submitted: u64,
    shed: u64,
    replied_ok: u64,
    replied_err: u64,
    /// Receiver closed without any reply — must stay zero.
    lost: u64,
}

type Pending = VecDeque<(Instant, std::sync::mpsc::Receiver<nncg::coordinator::ServeResult>)>;

/// Wait out the oldest in-flight request and account for its reply.
fn settle(inflight: &mut Pending, tally: &mut ClientTally, histo: &mut LatencyHisto) {
    if let Some((t, rx)) = inflight.pop_front() {
        match rx.recv() {
            Ok(Ok(_)) => {
                tally.replied_ok += 1;
                histo.record_us(t.elapsed().as_secs_f64() * 1e6);
            }
            Ok(Err(_)) => {
                tally.replied_err += 1;
                histo.record_us(t.elapsed().as_secs_f64() * 1e6);
            }
            Err(_) => tally.lost += 1,
        }
    }
}

/// TCP-mode counterpart of [`settle`]: replies arrive in submission order
/// on the connection, so the oldest in-flight send is always the next
/// frame off the wire. A remote queue-full reply is a shed (matching the
/// in-process submit-time `QueueFull` accounting); any other remote error
/// is a replied error; a transport failure is a lost request — the gate
/// requires zero of those.
fn settle_tcp(
    client: &mut NetClient,
    inflight: &mut VecDeque<Instant>,
    tally: &mut ClientTally,
    histo: &mut LatencyHisto,
) {
    if let Some(t) = inflight.pop_front() {
        match client.read_reply() {
            Ok((_, Ok(_))) => {
                tally.replied_ok += 1;
                histo.record_us(t.elapsed().as_secs_f64() * 1e6);
            }
            Ok((_, Err(e))) if e.kind() == "queue-full" => tally.shed += 1,
            Ok((_, Err(_))) => {
                tally.replied_err += 1;
                histo.record_us(t.elapsed().as_secs_f64() * 1e6);
            }
            Err(_) => tally.lost += 1,
        }
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("NNCG_BENCH_QUICK").is_ok();
    let requests = env_usize("NNCG_LOAD_REQUESTS", if quick { 20_000 } else { 1_000_000 });
    let shards = env_usize("NNCG_LOAD_SHARDS", 4).max(1);
    let clients = env_usize("NNCG_LOAD_CLIENTS", 4).max(1);
    let window = env_usize("NNCG_LOAD_WINDOW", 256).max(1);
    let chaos = !matches!(std::env::var("NNCG_LOAD_CHAOS").as_deref(), Ok("off") | Ok("0"));
    let seed = env_usize("NNCG_CHAOS_SEED", 1) as u64;
    // NNCG_LOAD_TCP=1 drives the pool over loopback TCP (the length-
    // prefixed frame protocol) instead of the in-process Submitter.
    let tcp = matches!(std::env::var("NNCG_LOAD_TCP").as_deref(), Ok("1") | Ok("on"));
    let steal_policy = std::env::var("NNCG_SERVE_STEAL_POLICY")
        .ok()
        .and_then(|v| StealPolicy::parse(v.trim()))
        .unwrap_or_default();

    // The three paper models; generated-C engines when a compiler exists.
    let specs = [
        ("ball", zoo::ball_classifier().with_random_weights(11)),
        ("pedestrian", zoo::pedestrian_classifier().with_random_weights(12)),
        ("robot", zoo::robot_detector().with_random_weights(13)),
    ];
    let have_cc = CcDriver::detect().is_ok();
    let router = Arc::new(Router::new());
    let mut engine_kinds = Vec::new();
    let mut input_dims: Vec<Vec<usize>> = Vec::new();
    for (name, model) in &specs {
        input_dims.push(model.input.dims().to_vec());
        let engine: Arc<dyn InferenceEngine> = if have_cc {
            let dir = std::env::temp_dir().join("nncg-load-serving");
            std::fs::create_dir_all(&dir)?;
            match CompiledCnn::build(model, &CodegenOptions::sse3(), &dir) {
                Ok(cnn) => {
                    engine_kinds.push((name.to_string(), "generated-c".to_string()));
                    Arc::new(cnn)
                }
                Err(e) => {
                    eprintln!("[load] {name}: compile failed ({e:#}); using interpreter");
                    engine_kinds.push((name.to_string(), "interp".to_string()));
                    Arc::new(InterpEngine::new(model.clone())?)
                }
            }
        } else {
            engine_kinds.push((name.to_string(), "interp".to_string()));
            Arc::new(InterpEngine::new(model.clone())?)
        };
        router.register(name, engine);
    }

    // Seeded chaos at the shard seams: rare worker kills (the queue
    // survives and is stolen) and steal-race delays.
    let plan = if chaos {
        Some(
            FaultPlan::builder(seed)
                .site(FaultSite::ShardKill, FaultSpec::Prob(0.0005))
                .site(FaultSite::StealRace, FaultSpec::Every(97))
                .delay(Duration::from_millis(1))
                .build(),
        )
    } else {
        None
    };

    // Batched dequeue: NNCG_LOAD_BATCH_MAX caps the per-shard batch width
    // (default 8 — the load bench exists to exercise the batched engine
    // entry), NNCG_LOAD_BATCH_ADAPT=off pins the width instead of adapting
    // it to queue depth.
    let batch_max = env_usize("NNCG_LOAD_BATCH_MAX", 8).max(1);
    let batch_adapt = batch_max > 1
        && !matches!(std::env::var("NNCG_LOAD_BATCH_ADAPT").as_deref(), Ok("off") | Ok("0"));
    let batch = if batch_max > 1 {
        BatcherPolicy::batched(batch_max, Duration::from_millis(2))
    } else {
        BatcherPolicy::immediate()
    };

    let handle = serve_sharded(
        Arc::clone(&router),
        ShardConfig {
            shards,
            workers_per_shard: env_usize("NNCG_LOAD_WORKERS", 1).max(1),
            queue_capacity: 8192,
            steal: true,
            steal_policy,
            batch,
            batch_adapt,
            breaker: BreakerConfig { failure_threshold: 16, cooldown: Duration::from_millis(50) },
            faults: plan.clone(),
            ..ShardConfig::default()
        },
    );
    // Loopback TCP front-end; the per-connection window matches the client
    // window so the socket, not the server channel, is the backpressure.
    let net = if tcp {
        Some(NetServer::start(
            handle.submitter(),
            "127.0.0.1:0",
            NetConfig { window, faults: plan, ..NetConfig::default() },
        )?)
    } else {
        None
    };
    let net_addr = net.as_ref().map(|s| s.local_addr());
    let heal = Arc::new(
        HealPipeline::new(Arc::clone(&router)).with_counters(Arc::clone(handle.metrics.counters())),
    );

    println!(
        "load_serving: {requests} requests, {shards} shards, {clients} clients, window {window}, \
         chaos {}, transport {}, steal-policy {}, engines {:?}",
        if chaos { "on" } else { "off" },
        if tcp { "tcp" } else { "in-process" },
        steal_policy.name(),
        engine_kinds
    );

    // Chaos driver: recycle shards and heal models while the load runs.
    let done = Arc::new(AtomicBool::new(false));
    // (Shard recycles need `&handle`, which is single-owner, so the main
    // thread drives those below; this thread drives the heal pipeline.)
    let chaos_thread = if chaos {
        let done = Arc::clone(&done);
        let heal = Arc::clone(&heal);
        let heal_models: Vec<(String, nncg::graph::Model)> =
            specs.iter().map(|(n, m)| (n.to_string(), m.clone())).collect();
        Some(std::thread::spawn(move || {
            let mut i = 0usize;
            while !done.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(400));
                if done.load(Ordering::SeqCst) {
                    break;
                }
                // Background heal of a rotating model: rebuild + hot-swap.
                let (name, model) = &heal_models[i % heal_models.len()];
                let m = model.clone();
                heal.request_rebuild(name, move || {
                    Ok(Arc::new(InterpEngine::new(m)?) as Arc<dyn InferenceEngine>)
                });
                i += 1;
            }
            heal.wait_idle()
        }))
    } else {
        None
    };

    // Client load threads.
    let t0 = Instant::now();
    let per_client = requests / clients;
    let remainder = requests - per_client * clients;
    let mut joins = Vec::new();
    for c in 0..clients {
        let n = per_client + if c == 0 { remainder } else { 0 };
        let submitter = handle.submitter();
        let dims = input_dims.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = XorShift64::new(seed.wrapping_mul(1_000_003).wrapping_add(c as u64 + 1));
            // One pre-built input per model: the benchmark measures the
            // serving path, not tensor generation.
            let inputs: Vec<Tensor> =
                dims.iter().map(|d| Tensor::rand(d, 0.0, 1.0, &mut rng)).collect();
            let names = ["ball", "pedestrian", "robot"];
            let mut tally = ClientTally::default();
            let mut histo = LatencyHisto::new();
            if let Some(addr) = net_addr {
                // Wire path: one connection per client, pipelined to the
                // same in-flight window as the in-process mode.
                let mut client = NetClient::connect(addr).expect("connect loopback net server");
                let mut inflight: VecDeque<Instant> = VecDeque::with_capacity(window);
                for _ in 0..n {
                    let pick = match rng.below(100) {
                        0..=89 => 0,
                        90..=97 => 1,
                        _ => 2,
                    };
                    tally.submitted += 1;
                    match client.send(names[pick], &inputs[pick]) {
                        Ok(_) => {
                            inflight.push_back(Instant::now());
                            if inflight.len() >= window {
                                settle_tcp(&mut client, &mut inflight, &mut tally, &mut histo);
                            }
                        }
                        Err(e) => {
                            eprintln!("[load] tcp send failed: {e}");
                            tally.lost += 1;
                        }
                    }
                }
                while !inflight.is_empty() {
                    settle_tcp(&mut client, &mut inflight, &mut tally, &mut histo);
                }
                return (tally, histo);
            }
            let mut inflight: Pending = VecDeque::with_capacity(window);
            for _ in 0..n {
                // Paper mix: ball-heavy embedded vision loop.
                let pick = match rng.below(100) {
                    0..=89 => 0,
                    90..=97 => 1,
                    _ => 2,
                };
                tally.submitted += 1;
                match submitter.submit(names[pick], inputs[pick].clone(), None) {
                    Ok(rx) => {
                        inflight.push_back((Instant::now(), rx));
                        if inflight.len() >= window {
                            settle(&mut inflight, &mut tally, &mut histo);
                        }
                    }
                    Err(ServeError::QueueFull { .. }) => tally.shed += 1,
                    Err(e) => {
                        eprintln!("[load] unexpected submission error: {e:?}");
                        tally.lost += 1;
                    }
                }
            }
            while !inflight.is_empty() {
                settle(&mut inflight, &mut tally, &mut histo);
            }
            (tally, histo)
        }));
    }

    // Drive shard recycles from the main thread while clients run (the
    // handle is single-owner): a rolling drain/restart across the pool.
    let mut recycles = 0usize;
    if chaos {
        let ball_home = home_shard("ball", shards);
        while joins.iter().any(|j| !j.is_finished()) {
            std::thread::sleep(Duration::from_millis(300));
            if joins.iter().all(|j| j.is_finished()) {
                break;
            }
            let idx = (ball_home + recycles) % shards;
            if handle.recycle_shard(idx) {
                recycles += 1;
            }
            if recycles >= shards * 2 {
                break; // two full rolling restarts is plenty of chaos
            }
        }
    }

    let mut total = ClientTally::default();
    let mut histo = LatencyHisto::new();
    for j in joins {
        let (t, h) = j.join().expect("client thread must not panic");
        total.submitted += t.submitted;
        total.shed += t.shed;
        total.replied_ok += t.replied_ok;
        total.replied_err += t.replied_err;
        total.lost += t.lost;
        histo.merge(&h);
    }
    done.store(true, Ordering::SeqCst);
    let heals_done = chaos_thread.map(|t| t.join().unwrap_or(0)).unwrap_or(0);
    let elapsed = t0.elapsed().as_secs_f64();
    // Stop the wire before the pool so every accepted frame has its reply
    // on the socket before the shard queues drain.
    if let Some(server) = net {
        server.stop();
    }
    let snap = handle.stop();

    let replied = total.replied_ok + total.replied_err;
    let req_per_s = replied as f64 / elapsed.max(1e-9);
    println!(
        "submitted={} replied_ok={} replied_err={} shed={} lost={} in {:.2}s ({:.0} req/s)",
        total.submitted, total.replied_ok, total.replied_err, total.shed, total.lost, elapsed, req_per_s
    );
    println!(
        "latency: mean={:.0}us p50<{:.0}us p99<{:.0}us p999<{:.0}us (client-side, n={})",
        histo.mean_us(),
        histo.quantile_us(0.50),
        histo.quantile_us(0.99),
        histo.quantile_us(0.999),
        histo.count()
    );
    println!(
        "batching: max={} adapt={} batched-infers={} batched-requests={} batch-mean={:.2} batch-size-max={}",
        batch_max,
        batch_adapt,
        snap.batched_infers,
        snap.batched_requests,
        snap.batch_size_mean(),
        snap.batch_size_max
    );
    if tcp {
        println!(
            "net: connections={} frames={} replies={} bad-frames={} dropped-conns={} unknown-rejects={}",
            snap.net_connections,
            snap.net_frames,
            snap.net_replies,
            snap.net_bad_frames,
            snap.net_dropped_conns,
            snap.net_unknown_rejects
        );
    }
    println!(
        "chaos: steals={} respawns={} ejects={} probes={} readmits={} drains={} heals={}/{} recycles={}",
        snap.steals,
        snap.worker_respawns,
        snap.shard_ejects,
        snap.shard_probes,
        snap.shard_readmits,
        snap.shard_drains,
        snap.heals_succeeded,
        heals_done,
        recycles
    );
    for s in &snap.shards {
        println!(
            "  shard {}: handled={} failed={} stolen-from={} stolen-by={} respawns={}",
            s.idx, s.handled, s.failed, s.stolen_from, s.stolen_by, s.respawns
        );
    }

    // Exactly-one-reply accounting gate.
    let mut gate_ok = true;
    if total.lost != 0 {
        eprintln!("GATE FAIL: {} requests lost (receiver closed without a reply)", total.lost);
        gate_ok = false;
    }
    if total.submitted != replied + total.shed {
        eprintln!(
            "GATE FAIL: submitted {} != replied {} + shed {}",
            total.submitted, replied, total.shed
        );
        gate_ok = false;
    }
    // The adaptive policy may widen batches only up to the configured cap.
    if snap.batch_size_max > batch_max as u64 {
        eprintln!(
            "GATE FAIL: realized batch width {} exceeds --batch-max {}",
            snap.batch_size_max, batch_max
        );
        gate_ok = false;
    }

    let doc = Value::Object(vec![
        ("bench".to_string(), Value::Str("load_serving".to_string())),
        ("source".to_string(), Value::Str("measured".to_string())),
        ("requests".to_string(), Value::Num(total.submitted as f64)),
        ("shards".to_string(), Value::Num(shards as f64)),
        ("clients".to_string(), Value::Num(clients as f64)),
        ("chaos".to_string(), Value::Bool(chaos)),
        (
            "engines".to_string(),
            Value::Object(
                engine_kinds.iter().map(|(m, k)| (m.clone(), Value::Str(k.clone()))).collect(),
            ),
        ),
        ("elapsed_s".to_string(), Value::Num((elapsed * 1000.0).round() / 1000.0)),
        ("req_per_s".to_string(), Value::Num(req_per_s.round())),
        ("latency_mean_us".to_string(), Value::Num(histo.mean_us().round())),
        ("latency_p50_us".to_string(), Value::Num(histo.quantile_us(0.50).round())),
        ("latency_p99_us".to_string(), Value::Num(histo.quantile_us(0.99).round())),
        ("latency_p999_us".to_string(), Value::Num(histo.quantile_us(0.999).round())),
        ("replied_ok".to_string(), Value::Num(total.replied_ok as f64)),
        ("replied_err".to_string(), Value::Num(total.replied_err as f64)),
        ("shed".to_string(), Value::Num(total.shed as f64)),
        ("lost".to_string(), Value::Num(total.lost as f64)),
        ("batch_max".to_string(), Value::Num(batch_max as f64)),
        ("batch_adapt".to_string(), Value::Bool(batch_adapt)),
        ("batched_infers".to_string(), Value::Num(snap.batched_infers as f64)),
        ("batched_requests".to_string(), Value::Num(snap.batched_requests as f64)),
        ("batch_size_mean".to_string(), Value::Num((snap.batch_size_mean() * 100.0).round() / 100.0)),
        ("batch_size_max".to_string(), Value::Num(snap.batch_size_max as f64)),
        ("transport".to_string(), Value::Str(if tcp { "tcp" } else { "in-process" }.to_string())),
        ("steal_policy".to_string(), Value::Str(steal_policy.name().to_string())),
        ("net_frames".to_string(), Value::Num(snap.net_frames as f64)),
        ("net_replies".to_string(), Value::Num(snap.net_replies as f64)),
        ("steals".to_string(), Value::Num(snap.steals as f64)),
        ("worker_respawns".to_string(), Value::Num(snap.worker_respawns as f64)),
        ("shard_drains".to_string(), Value::Num(snap.shard_drains as f64)),
        ("heals_succeeded".to_string(), Value::Num(snap.heals_succeeded as f64)),
        ("accounting_gate".to_string(), Value::Bool(gate_ok)),
    ]);
    std::fs::write("BENCH_serving.json", doc.to_json() + "\n")?;
    println!("wrote BENCH_serving.json (gate {})", if gate_ok { "OK" } else { "FAIL" });

    if !gate_ok {
        std::process::exit(1);
    }
    Ok(())
}
