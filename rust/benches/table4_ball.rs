//! Reproduces paper Table IV: execution time of the ball classifier.
//! Host rows are measured; paper platforms are cost-model simulated.
//! `NNCG_BENCH_QUICK=1` shortens the run for CI-style smoke checks.

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("NNCG_BENCH_QUICK").is_ok();
    let result = nncg::experiments::run_table4(quick)?;
    println!("{}", result.rendered);
    Ok(())
}
