//! Reproduces the paper's §III-C GPU observations on the simulated GTX
//! 1050: single-image latency ~5.6ms regardless of CNN size, flat below
//! ~100 images, amortizing only at large batches — versus the measured
//! host CPU latency of the generated C.

fn main() -> anyhow::Result<()> {
    let result = nncg::experiments::run_gpu_throughput()?;
    println!("{}", result.rendered);
    Ok(())
}
