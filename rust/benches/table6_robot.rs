//! Reproduces paper Table VI: execution time of the robot detector.

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("NNCG_BENCH_QUICK").is_ok();
    let result = nncg::experiments::run_table6(quick)?;
    println!("{}", result.rendered);
    Ok(())
}
