/*
 * Declaration-only stand-in for <arm_neon.h>, used to SYNTAX-CHECK
 * NNCG's NEON-generated C on x86 CI hosts (gcc -fsyntax-only -isystem
 * ci/stubs). It declares exactly the vocabulary the generator's NEON
 * OpTable emits (rust/src/codegen/simd.rs) — nothing here is callable;
 * never link against this. Real ARM builds use the toolchain header.
 */
#ifndef NNCG_STUB_ARM_NEON_H
#define NNCG_STUB_ARM_NEON_H

typedef struct {
    float nncg_stub_lanes[4];
} float32x4_t;

float32x4_t vld1q_f32(const float *ptr);
void vst1q_f32(float *ptr, float32x4_t val);
float32x4_t vdupq_n_f32(float value);
float32x4_t vaddq_f32(float32x4_t a, float32x4_t b);
float32x4_t vmulq_f32(float32x4_t a, float32x4_t b);
float32x4_t vmaxq_f32(float32x4_t a, float32x4_t b);
float32x4_t vfmaq_f32(float32x4_t a, float32x4_t b, float32x4_t c);
float vaddvq_f32(float32x4_t a);

#endif /* NNCG_STUB_ARM_NEON_H */
