/*
 * Declaration-only stand-in for <arm_neon.h>, used to SYNTAX-CHECK
 * NNCG's NEON-generated C on x86 CI hosts (gcc -fsyntax-only -isystem
 * ci/stubs). It declares exactly the vocabulary the generator's NEON
 * OpTable emits (rust/src/codegen/simd.rs) — nothing here is callable;
 * never link against this. Real ARM builds use the toolchain header.
 */
#ifndef NNCG_STUB_ARM_NEON_H
#define NNCG_STUB_ARM_NEON_H

typedef struct {
    float nncg_stub_lanes[4];
} float32x4_t;

typedef struct {
    float nncg_stub_lanes[2];
} float32x2_t;

float32x4_t vld1q_f32(const float *ptr);
void vst1q_f32(float *ptr, float32x4_t val);
float32x4_t vdupq_n_f32(float value);
float32x4_t vaddq_f32(float32x4_t a, float32x4_t b);
float32x4_t vmulq_f32(float32x4_t a, float32x4_t b);
float32x4_t vmaxq_f32(float32x4_t a, float32x4_t b);
float32x4_t vfmaq_f32(float32x4_t a, float32x4_t b, float32x4_t c);
/* pre-VFPv4 ARMv7 flavor (--isa neon-vfpv3): non-fused multiply-accumulate */
float32x4_t vmlaq_f32(float32x4_t a, float32x4_t b, float32x4_t c);
float vaddvq_f32(float32x4_t a);
/* ARMv7-safe pairwise reduction vocabulary */
float32x2_t vget_low_f32(float32x4_t a);
float32x2_t vget_high_f32(float32x4_t a);
float32x2_t vpadd_f32(float32x2_t a, float32x2_t b);
float vget_lane_f32(float32x2_t a, int lane);

/* --dtype int8 vocabulary (rust/src/codegen/simd.rs QNEON / QNEON_DOT) */
typedef struct {
    int nncg_stub_lanes[4];
} int32x4_t;

typedef struct {
    short nncg_stub_lanes[4];
} int16x4_t;

typedef struct {
    signed char nncg_stub_lanes[16];
} int8x16_t;

int32x4_t vld1q_s32(const int *ptr);
void vst1q_s32(int *ptr, int32x4_t val);
int16x4_t vld1_s16(const short *ptr);
int16x4_t vdup_n_s16(short value);
/* widening multiply-accumulate: int16 x int16 + int32, exact */
int32x4_t vmlal_s16(int32x4_t a, int16x4_t b, int16x4_t c);
/* ARMv8.2+dotprod flavor (--isa neon-dot) */
int8x16_t vld1q_s8(const signed char *ptr);
int32x4_t vdupq_n_s32(int value);
int8x16_t vreinterpretq_s8_s32(int32x4_t a);
int32x4_t vdotq_s32(int32x4_t a, int8x16_t b, int8x16_t c);

#endif /* NNCG_STUB_ARM_NEON_H */
