//! Minimal offline stand-in for the `libloading` crate (Unix only).
//!
//! Wraps `dlopen`/`dlsym`/`dlclose` with the same call shapes nncg uses:
//! `unsafe { Library::new(path) }`, `lib.get::<T>(b"symbol\0")` returning a
//! [`Symbol<T>`] that derefs to the raw function pointer.

#![cfg(unix)]

use std::ffi::{CStr, CString, OsStr};
use std::fmt;
use std::marker::PhantomData;
use std::os::raw::{c_char, c_int, c_void};
use std::os::unix::ffi::OsStrExt;

#[cfg_attr(target_os = "linux", link(name = "dl"))]
extern "C" {
    fn dlopen(filename: *const c_char, flags: c_int) -> *mut c_void;
    fn dlsym(handle: *mut c_void, symbol: *const c_char) -> *mut c_void;
    fn dlclose(handle: *mut c_void) -> c_int;
    fn dlerror() -> *mut c_char;
}

const RTLD_NOW: c_int = 2;

/// Library loading / symbol resolution error.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

unsafe fn take_dlerror(fallback: &str) -> Error {
    let p = dlerror();
    let msg = if p.is_null() {
        fallback.to_string()
    } else {
        CStr::from_ptr(p).to_string_lossy().into_owned()
    };
    Error { msg }
}

/// A loaded shared object. Closed (dlclose) on drop.
pub struct Library {
    handle: *mut c_void,
}

// SAFETY: a dlopen handle is process-global state; dlsym/dlclose on it are
// thread-safe per POSIX.
unsafe impl Send for Library {}
unsafe impl Sync for Library {}

impl Library {
    /// Load a shared object.
    ///
    /// # Safety
    /// Loading a library executes its initializers.
    pub unsafe fn new<P: AsRef<OsStr>>(path: P) -> Result<Library, Error> {
        let c = CString::new(path.as_ref().as_bytes())
            .map_err(|_| Error { msg: "library path contains NUL".into() })?;
        let _ = dlerror(); // clear any stale error
        let handle = dlopen(c.as_ptr(), RTLD_NOW);
        if handle.is_null() {
            return Err(take_dlerror("dlopen failed"));
        }
        Ok(Library { handle })
    }

    /// Resolve a symbol. The byte string may or may not be NUL-terminated.
    ///
    /// # Safety
    /// The caller asserts the symbol really has type `T` (which must be
    /// pointer-sized, e.g. a function pointer).
    pub unsafe fn get<'lib, T>(&'lib self, symbol: &[u8]) -> Result<Symbol<'lib, T>, Error> {
        assert_eq!(
            std::mem::size_of::<T>(),
            std::mem::size_of::<*mut c_void>(),
            "Symbol<T> requires a pointer-sized T (function pointer)"
        );
        let owned: Vec<u8> = match symbol.last() {
            Some(0) => symbol[..symbol.len() - 1].to_vec(),
            _ => symbol.to_vec(),
        };
        let c = CString::new(owned).map_err(|_| Error { msg: "symbol contains interior NUL".into() })?;
        let _ = dlerror();
        let ptr = dlsym(self.handle, c.as_ptr());
        if ptr.is_null() {
            return Err(take_dlerror("dlsym returned NULL"));
        }
        Ok(Symbol {
            value: std::mem::transmute_copy::<*mut c_void, T>(&ptr),
            _lib: PhantomData,
        })
    }
}

impl Drop for Library {
    fn drop(&mut self) {
        unsafe {
            let _ = dlclose(self.handle);
        }
    }
}

impl fmt::Debug for Library {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Library({:p})", self.handle)
    }
}

/// A resolved symbol, borrowing the [`Library`] it came from.
pub struct Symbol<'lib, T> {
    value: T,
    _lib: PhantomData<&'lib Library>,
}

impl<'lib, T> std::ops::Deref for Symbol<'lib, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loading_a_missing_library_errors() {
        let err = unsafe { Library::new("/nonexistent/libnope.so") };
        assert!(err.is_err());
    }

    #[test]
    fn loads_libm_and_calls_cos() {
        // libm ships with every glibc install; fall back over sonames.
        let lib = ["libm.so.6", "libm.so"]
            .iter()
            .find_map(|n| unsafe { Library::new(n) }.ok());
        let lib = match lib {
            Some(l) => l,
            None => return, // unusual libc layout; skip
        };
        let cos: Symbol<unsafe extern "C" fn(f64) -> f64> =
            unsafe { lib.get(b"cos\0").unwrap() };
        let v = unsafe { (*cos)(0.0) };
        assert!((v - 1.0).abs() < 1e-12);
    }

    #[test]
    fn missing_symbol_errors() {
        let lib = match unsafe { Library::new("libm.so.6") } {
            Ok(l) => l,
            Err(_) => return,
        };
        let r: Result<Symbol<unsafe extern "C" fn()>, Error> =
            unsafe { lib.get(b"definitely_not_a_symbol") };
        assert!(r.is_err());
    }
}
