//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build container has no crates.io access, so this vendored shim
//! implements exactly the subset nncg uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the [`Context`] extension
//! trait. Semantics follow the real crate where it matters to callers:
//! `{}` prints the outermost message, `{:#}` prints the whole cause chain
//! separated by `: `, and `{:?}` prints the message plus a `Caused by:`
//! section.

use std::error::Error as StdError;
use std::fmt;

/// An error with a human-readable cause chain (outermost message first).
pub struct Error {
    chain: Vec<String>,
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    fn from_std(err: &(dyn StdError + 'static)) -> Error {
        let mut chain = vec![err.to_string()];
        let mut cur = err.source();
        while let Some(e) = cur {
            chain.push(e.to_string());
            cur = e.source();
        }
        Error { chain }
    }

    /// The outermost message.
    pub fn root_cause_chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

/// Conversion used by [`Context`]; implemented for std errors and for
/// [`Error`] itself so `.context()` chains on `anyhow::Result` too.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from_std(&self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, ()> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_option_and_anyhow_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: root cause");

        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");

        let ar: Result<()> = Err(anyhow!("inner {}", 3));
        let e = ar.with_context(|| "wrapped").unwrap_err();
        assert_eq!(format!("{e:#}"), "wrapped: inner 3");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).is_err());
        assert!(f(200).unwrap_err().to_string().contains("too big"));
    }
}
