//! Minimal offline stand-in for the `xla` PJRT bindings.
//!
//! The real crate links the native `xla_extension` runtime, which is not
//! available in this container. This shim keeps the same API shapes nncg
//! uses (`PjRtClient::cpu`, `HloModuleProto::from_text_file`,
//! `XlaComputation::from_proto`, `client.compile`, `exe.execute`,
//! `Literal`) and backs them with a tiny HLO-*text* interpreter covering
//! elementwise f32 modules: `parameter`, `constant`, `broadcast` (scalar),
//! `add`, `subtract`, `multiply`, `divide`, `maximum`, `tuple`.
//!
//! Modules using any other op (e.g. `convolution` from real CNN lowerings)
//! fail at `compile()` with a clear error, which callers already treat as
//! "XLA backend unavailable" (N/A columns, skipped tests).

use std::fmt;

/// Error type for parse/compile/execute failures.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types this shim evaluates (f32 only).
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// A dense f32 literal, possibly a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<usize>,
    data: Vec<f32>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { dims: vec![v.len()], data: v.to_vec(), tuple: None }
    }

    fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: vec![v], tuple: None }
    }

    fn dense(dims: Vec<usize>, data: Vec<f32>) -> Literal {
        Literal { dims, data, tuple: None }
    }

    fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: vec![], tuple: Some(parts) }
    }

    fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Unwrap a 1-element tuple (jax `return_tuple=True` convention).
    pub fn to_tuple1(&self) -> Result<Literal> {
        match &self.tuple {
            Some(parts) if parts.len() == 1 => Ok(parts[0].clone()),
            Some(parts) => Err(Error::new(format!("expected 1-tuple, got {}-tuple", parts.len()))),
            None => Err(Error::new("literal is not a tuple")),
        }
    }

    /// Copy out the flat element data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error::new("cannot convert a tuple literal to a flat vec"));
        }
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// One parsed HLO instruction.
#[derive(Debug, Clone)]
struct Instr {
    name: String,
    dims: Vec<usize>,
    is_tuple_type: bool,
    op: String,
    args: Vec<String>,
}

/// A parsed HLO module (entry computation only).
#[derive(Debug, Clone)]
struct HloModule {
    instrs: Vec<Instr>,
    root: usize,
}

const SUPPORTED_OPS: [&str; 9] = [
    "parameter", "constant", "broadcast", "add", "subtract", "multiply", "divide", "maximum",
    "tuple",
];

fn parse_shape(s: &str) -> Result<(Vec<usize>, bool)> {
    // "(f32[4]{0})" → tuple of one; "f32[4]{0}" / "f32[]" / "f32[2,3]{1,0}"
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('(') {
        let inner = inner.strip_suffix(')').ok_or_else(|| Error::new("unbalanced tuple type"))?;
        // Only single-element tuple types are needed here.
        let (dims, _) = parse_shape(inner)?;
        return Ok((dims, true));
    }
    let rest = s
        .strip_prefix("f32")
        .ok_or_else(|| Error::new(format!("unsupported element type in {s:?} (only f32)")))?;
    let open = rest.find('[').ok_or_else(|| Error::new(format!("missing [dims] in {s:?}")))?;
    let close = rest.find(']').ok_or_else(|| Error::new(format!("missing ] in {s:?}")))?;
    let dims_str = &rest[open + 1..close];
    let dims: Vec<usize> = if dims_str.trim().is_empty() {
        vec![]
    } else {
        dims_str
            .split(',')
            .map(|d| d.trim().parse::<usize>().map_err(|_| Error::new(format!("bad dim {d:?}"))))
            .collect::<Result<Vec<usize>>>()?
    };
    Ok((dims, false))
}

fn parse_instruction(line: &str) -> Result<(bool, Instr)> {
    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    let eq = line.find(" = ").ok_or_else(|| Error::new(format!("no `=` in instruction {line:?}")))?;
    let name = line[..eq].trim().to_string();
    let rhs = line[eq + 3..].trim();

    // The type token: balanced parens for tuple types, else up to first space.
    let type_end = if rhs.starts_with('(') {
        let mut depth = 0usize;
        let mut end = 0usize;
        for (i, c) in rhs.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        end = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        if end == 0 {
            return Err(Error::new(format!("unbalanced type in {line:?}")));
        }
        end
    } else {
        rhs.find(' ').ok_or_else(|| Error::new(format!("no op after type in {line:?}")))?
    };
    let (dims, is_tuple_type) = parse_shape(&rhs[..type_end])?;
    let rest = rhs[type_end..].trim();

    let paren = rest.find('(').ok_or_else(|| Error::new(format!("no operand list in {line:?}")))?;
    let op = rest[..paren].trim().to_string();
    let close = rest[paren..]
        .find(')')
        .map(|i| paren + i)
        .ok_or_else(|| Error::new(format!("unterminated operand list in {line:?}")))?;
    let args: Vec<String> = rest[paren + 1..close]
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    // Trailing attributes (", dimensions={}" etc.) are ignored.
    Ok((is_root, Instr { name, dims, is_tuple_type, op, args }))
}

fn parse_module(text: &str) -> Result<HloModule> {
    let mut instrs = Vec::new();
    let mut root = None;
    let mut in_entry = false;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("HloModule") || line.starts_with("//") {
            continue;
        }
        if line.starts_with("ENTRY ") {
            in_entry = true;
            continue;
        }
        if !in_entry {
            // Non-entry computations (fusions, reducers) are unsupported.
            if line.contains(" = ") {
                return Err(Error::new("non-entry computations are not supported by the xla shim"));
            }
            continue;
        }
        if line == "}" {
            in_entry = false;
            continue;
        }
        let (is_root, instr) = parse_instruction(line)?;
        if is_root {
            root = Some(instrs.len());
        }
        instrs.push(instr);
    }
    let root = root.ok_or_else(|| Error::new("module has no ROOT instruction"))?;
    Ok(HloModule { instrs, root })
}

/// Parsed HLO module handle (mirrors `xla::HloModuleProto`).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    module: HloModule,
}

impl HloModuleProto {
    /// Parse an HLO text file (the format `python/compile/aot.py` writes).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { module: parse_module(&text)? })
    }
}

/// A computation ready for compilation (mirrors `xla::XlaComputation`).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    module: HloModule,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.module.clone() }
    }
}

/// CPU "client" (the shim has no devices; it interprets in-process).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Validate that the module only uses ops the interpreter supports.
    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        for instr in &computation.module.instrs {
            if !SUPPORTED_OPS.contains(&instr.op.as_str()) {
                return Err(Error::new(format!(
                    "HLO op {:?} is not supported by the offline xla shim",
                    instr.op
                )));
            }
        }
        Ok(PjRtLoadedExecutable { module: computation.module.clone() })
    }
}

/// An executable module (mirrors `xla::PjRtLoadedExecutable`).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    module: HloModule,
}

/// A device buffer holding a result (mirrors `xla::PjRtBuffer`).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

impl PjRtLoadedExecutable {
    /// Execute on host literals; returns per-device, per-output buffers
    /// (one device, one output here).
    pub fn execute<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let args: Vec<&Literal> = args.iter().map(|a| a.borrow()).collect();
        let result = interpret(&self.module, &args)?;
        Ok(vec![vec![PjRtBuffer { literal: result }]])
    }
}

fn interpret(module: &HloModule, args: &[&Literal]) -> Result<Literal> {
    let mut env: Vec<Literal> = Vec::with_capacity(module.instrs.len());
    let lookup = |env: &[Literal], instrs: &[Instr], name: &str| -> Result<Literal> {
        instrs
            .iter()
            .position(|i| i.name == name)
            .and_then(|i| env.get(i).cloned())
            .ok_or_else(|| Error::new(format!("operand {name:?} not yet defined")))
    };
    for instr in &module.instrs {
        let value = match instr.op.as_str() {
            "parameter" => {
                let idx: usize = instr
                    .args
                    .first()
                    .and_then(|a| a.parse().ok())
                    .ok_or_else(|| Error::new("bad parameter index"))?;
                let arg = args
                    .get(idx)
                    .ok_or_else(|| Error::new(format!("missing argument {idx}")))?;
                let want: usize = instr.dims.iter().product();
                if arg.numel() != want {
                    return Err(Error::new(format!(
                        "argument {idx} has {} elements, parameter wants {want}",
                        arg.numel()
                    )));
                }
                Literal::dense(instr.dims.clone(), arg.data.clone())
            }
            "constant" => {
                let v: f32 = instr
                    .args
                    .first()
                    .and_then(|a| a.parse().ok())
                    .ok_or_else(|| Error::new("non-scalar constants are not supported"))?;
                Literal::scalar(v)
            }
            "broadcast" => {
                let src = lookup(&env, &module.instrs, &instr.args[0])?;
                let n: usize = instr.dims.iter().product();
                if src.numel() == 1 {
                    Literal::dense(instr.dims.clone(), vec![src.data[0]; n])
                } else if src.numel() == n {
                    Literal::dense(instr.dims.clone(), src.data)
                } else {
                    return Err(Error::new("only scalar broadcast is supported"));
                }
            }
            "add" | "subtract" | "multiply" | "divide" | "maximum" => {
                let a = lookup(&env, &module.instrs, &instr.args[0])?;
                let b = lookup(&env, &module.instrs, &instr.args[1])?;
                if a.numel() != b.numel() {
                    return Err(Error::new("elementwise operands differ in size"));
                }
                let data: Vec<f32> = a
                    .data
                    .iter()
                    .zip(&b.data)
                    .map(|(&x, &y)| match instr.op.as_str() {
                        "add" => x + y,
                        "subtract" => x - y,
                        "multiply" => x * y,
                        "divide" => x / y,
                        _ => x.max(y),
                    })
                    .collect();
                Literal::dense(instr.dims.clone(), data)
            }
            "tuple" => {
                let parts = instr
                    .args
                    .iter()
                    .map(|a| lookup(&env, &module.instrs, a))
                    .collect::<Result<Vec<Literal>>>()?;
                Literal::tuple(parts)
            }
            other => return Err(Error::new(format!("unsupported op {other:?}"))),
        };
        let _ = instr.is_tuple_type;
        env.push(value);
    }
    Ok(env[module.root].clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_f, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main.5 {
  Arg_0.1 = f32[4]{0} parameter(0)
  constant.2 = f32[] constant(2)
  broadcast.3 = f32[4]{0} broadcast(constant.2), dimensions={}
  multiply.4 = f32[4]{0} multiply(Arg_0.1, broadcast.3)
  ROOT tuple.5 = (f32[4]{0}) tuple(multiply.4)
}
"#;

    fn run(text: &str, input: &[f32]) -> Result<Vec<f32>> {
        let module = parse_module(text)?;
        let comp = XlaComputation { module };
        let exe = PjRtClient::cpu()?.compile(&comp)?;
        let lit = Literal::vec1(input);
        let out = exe.execute::<Literal>(&[lit])?[0][0].to_literal_sync()?;
        out.to_tuple1()?.to_vec::<f32>()
    }

    #[test]
    fn doubles_through_the_full_api() {
        let y = run(SAMPLE, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(y, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn unsupported_ops_fail_at_compile() {
        let text = SAMPLE.replace("multiply", "convolution");
        let module = parse_module(&text).unwrap();
        let comp = XlaComputation { module };
        assert!(PjRtClient::cpu().unwrap().compile(&comp).is_err());
    }

    #[test]
    fn wrong_arity_is_an_execute_error() {
        let module = parse_module(SAMPLE).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation { module }).unwrap();
        let out = exe.execute::<Literal>(&[Literal::vec1(&[1.0])]);
        assert!(out.is_err());
    }

    #[test]
    fn shape_parser() {
        assert_eq!(parse_shape("f32[4]{0}").unwrap(), (vec![4], false));
        assert_eq!(parse_shape("f32[]").unwrap(), (vec![], false));
        assert_eq!(parse_shape("f32[2,3]{1,0}").unwrap(), (vec![2, 3], false));
        assert_eq!(parse_shape("(f32[4]{0})").unwrap(), (vec![4], true));
        assert!(parse_shape("s32[4]").is_err());
    }
}
