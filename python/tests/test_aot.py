"""AOT lowering tests: HLO text properties the Rust runtime depends on."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import flat_fn, load_params, lower_model, to_hlo_text
from compile.model import ARCHS


@pytest.mark.parametrize("name", list(ARCHS))
def test_lowered_hlo_text_interface(name, tmp_path):
    text = lower_model(name, str(tmp_path))  # no weights dir -> seeded init
    # interface the Rust loader assumes: single flat f32 param, 1-tuple out
    in_numel = int(np.prod(ARCHS[name]["input"]))
    assert f"f32[{in_numel}]" in text
    assert "ENTRY" in text
    # the old parser reads elided constants as zeros -- must never appear
    assert "constant({...}" not in text, "large constants were elided!"


def test_flat_fn_matches_model_forward():
    params = load_params("ball", "/nonexistent")
    f, n = flat_fn("ball", params, use_pallas=True)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    from compile.model import forward

    want = forward(params, x.reshape(ARCHS["ball"]["input"]), "ball").reshape(-1)
    np.testing.assert_allclose(f(x)[0], want, rtol=1e-5, atol=1e-6)


def test_pallas_and_ref_lowerings_agree():
    """The exported computation must be the same function either way."""
    params = load_params("ball", "/nonexistent")
    f_pal, n = flat_fn("ball", params, use_pallas=True)
    f_ref, _ = flat_fn("ball", params, use_pallas=False)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    np.testing.assert_allclose(f_pal(x)[0], f_ref(x)[0], rtol=1e-4, atol=1e-5)


def test_weights_are_baked_as_constants():
    """P3 at the HLO level: no weight-shaped parameters in the module."""
    text = lower_model("ball", "/nonexistent")
    # the only parameter is the flat input
    entry = text.split("ENTRY", 1)[1]
    param_lines = [l for l in entry.splitlines() if "parameter(" in l]
    assert len(param_lines) >= 1
    in_numel = int(np.prod(ARCHS["ball"]["input"]))
    assert any(f"f32[{in_numel}]" in l for l in param_lines)
    # conv weights appear as constants, not parameters
    assert "f32[5,5,1,8]" in text
    assert not any("f32[5,5,1,8]" in l for l in param_lines)
