"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/strides/padding per the reproduction brief; every
case asserts allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.conv2d import conv2d_pallas, vmem_report
from compile.kernels.maxpool import maxpool2d_pallas
from compile.kernels.softmax import softmax_pallas

RTOL, ATOL = 1e-5, 1e-5


def rand(rng, shape, lo=-1.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, shape), jnp.float32)


# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------

conv_cases = st.tuples(
    st.integers(5, 14),            # h_in
    st.integers(5, 14),            # w_in
    st.integers(1, 3),             # c_in
    st.integers(1, 8),             # c_out
    st.sampled_from([(1, 1), (2, 2), (3, 3), (5, 5), (2, 3), (4, 2)]),  # kernel
    st.sampled_from([(1, 1), (2, 2), (1, 2), (2, 1), (3, 3)]),          # stride
    st.sampled_from(["same", "valid"]),
    st.integers(0, 2 ** 31 - 1),   # seed
)


@settings(max_examples=25, deadline=None)
@given(conv_cases)
def test_conv2d_matches_ref(case):
    h, w, ci, co, k, s, pad, seed = case
    if pad == "valid" and (k[0] > h or k[1] > w):
        return  # invalid geometry
    rng = np.random.default_rng(seed)
    x = rand(rng, (h, w, ci))
    wt = rand(rng, (k[0], k[1], ci, co))
    b = rand(rng, (co,))
    got = conv2d_pallas(x, wt, b, s, pad)
    want = ref.conv2d(x, wt, b, s, pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("act", ["relu", "leaky_relu"])
def test_conv2d_fused_activation(act):
    rng = np.random.default_rng(3)
    x = rand(rng, (8, 8, 2))
    wt = rand(rng, (3, 3, 2, 4))
    b = rand(rng, (4,))
    got = conv2d_pallas(x, wt, b, (1, 1), "same", act=act, alpha=0.1)
    base = ref.conv2d(x, wt, b, (1, 1), "same")
    want = ref.relu(base) if act == "relu" else ref.leaky_relu(base, 0.1)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_conv2d_paper_ball_geometry():
    """Table I first layer: 16x16x1, 8 filters 5x5, stride 2, same."""
    rng = np.random.default_rng(0)
    x = rand(rng, (16, 16, 1), 0, 1)
    wt = rand(rng, (5, 5, 1, 8))
    b = rand(rng, (8,))
    got = conv2d_pallas(x, wt, b, (2, 2), "same")
    assert got.shape == (8, 8, 8)
    np.testing.assert_allclose(got, ref.conv2d(x, wt, b, (2, 2), "same"), rtol=RTOL, atol=ATOL)


def test_conv2d_rejects_unknown_padding():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        conv2d_pallas(rand(rng, (4, 4, 1)), rand(rng, (3, 3, 1, 2)), rand(rng, (2,)), (1, 1), "full")


def test_vmem_report_small_models_fit():
    """The paper's nets are tiny: one grid step must be far below VMEM."""
    rep = vmem_report((60, 80, 3), (3, 3, 3, 8), (1, 1), "same")
    assert rep["vmem_fraction_16MiB"] < 0.01
    assert rep["macs_per_step"] > 0


# --------------------------------------------------------------------------
# maxpool
# --------------------------------------------------------------------------

pool_cases = st.tuples(
    st.integers(4, 16),
    st.integers(4, 16),
    st.integers(1, 8),
    st.sampled_from([(2, 2), (3, 3), (2, 3)]),
    st.sampled_from([(1, 1), (2, 2), (3, 3)]),
    st.integers(0, 2 ** 31 - 1),
)


@settings(max_examples=20, deadline=None)
@given(pool_cases)
def test_maxpool_matches_ref(case):
    h, w, c, pool, stride, seed = case
    if pool[0] > h or pool[1] > w:
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, (h, w, c))
    got = maxpool2d_pallas(x, pool, stride)
    want = ref.maxpool2d(x, pool, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_maxpool_negative_values():
    x = jnp.asarray(np.full((4, 4, 1), -5.0, np.float32))
    got = maxpool2d_pallas(x, (2, 2), (2, 2))
    assert float(got.max()) == -5.0


# --------------------------------------------------------------------------
# softmax
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_softmax_matches_ref(h, w, c, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (h, w, c), -5, 5)
    got = softmax_pallas(x)
    np.testing.assert_allclose(got, ref.softmax(x), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(float(jnp.sum(got)), 1.0, rtol=1e-5)


def test_softmax_is_stable_for_large_logits():
    x = jnp.asarray([[[1000.0, 1001.0]]], jnp.float32)
    got = softmax_pallas(x)
    assert bool(jnp.all(jnp.isfinite(got)))


# --------------------------------------------------------------------------
# batchnorm folding (Eq. 7)
# --------------------------------------------------------------------------


def test_fold_batchnorm_equivalence():
    rng = np.random.default_rng(5)
    x = rand(rng, (6, 6, 2))
    w = rand(rng, (3, 3, 2, 4))
    b = rand(rng, (4,))
    gamma, beta = rand(rng, (4,), 0.5, 1.5), rand(rng, (4,), -0.2, 0.2)
    mean, var = rand(rng, (4,), -0.5, 0.5), rand(rng, (4,), 0.25, 1.0)
    y1 = ref.batchnorm(ref.conv2d(x, w, b, (1, 1), "same"), gamma, beta, mean, var)
    wf, bf = ref.fold_batchnorm(w, b, gamma, beta, mean, var)
    y2 = ref.conv2d(x, wf, bf, (1, 1), "same")
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
