"""Trainer smoke tests: a few dozen steps must reduce the loss and the
tiny classifiers must beat chance on held-out synthetic data."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import datasets
from compile.model import forward, init_params
from compile.train import accuracy, adam_init, adam_update, classifier_loss, train_classifier, train_robot


def test_adam_reduces_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adam_init(params)
    import jax

    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adam_update(params, g, state, lr=0.1)
    assert float(loss(params)) < 1e-2


def test_ball_training_reduces_loss_and_beats_chance():
    logs = []
    params, acc = train_classifier("ball", steps=60, batch=16, lr=2e-3, seed=0, log=logs.append)
    # loss trend from the log lines
    losses = [float(l.split("loss ")[1].split(" ")[0]) for l in logs if l.startswith("step")]
    assert losses[-1] < losses[0], losses
    assert acc > 0.75, f"accuracy {acc} (chance = 0.5)"


def test_robot_training_reduces_loss():
    logs = []
    _params, last = train_robot(steps=8, batch=4, lr=1e-3, seed=0, log=logs.append)
    first = float(logs[0].split("loss ")[1].split(" ")[0])
    assert last < first, (first, last)


def test_classifier_loss_is_finite_and_positive():
    params = init_params("ball", 2)
    xs, ys = datasets.ball_batch(4, np.random.default_rng(0))
    l = classifier_loss(params, jnp.asarray(xs), jnp.asarray(ys), "ball")
    assert np.isfinite(float(l)) and float(l) > 0


def test_accuracy_of_untrained_is_near_chance():
    params = init_params("ball", 3)
    xs, ys = datasets.ball_batch(64, np.random.default_rng(1))
    acc = accuracy(params, jnp.asarray(xs), ys, "ball")
    assert 0.2 <= acc <= 0.8, acc
