"""Layer-2 tests: model shapes, pallas-vs-ref forward equality, BN folding,
and export/AOT plumbing."""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from compile import datasets
from compile.export import arch_json, read_nncgw, weight_records, write_nncgw
from compile.model import ARCHS, fold_bn_params, forward, forward_pallas, init_params, output_shape

SHAPES = {"ball": (16, 16, 1), "pedestrian": (36, 18, 1), "robot": (60, 80, 3)}
OUT_SHAPES = {"ball": (1, 1, 2), "pedestrian": (1, 1, 2), "robot": (15, 20, 20)}


@pytest.mark.parametrize("name", list(ARCHS))
def test_output_shapes_match_paper(name):
    assert output_shape(name) == OUT_SHAPES[name]


@pytest.mark.parametrize("name", list(ARCHS))
def test_pallas_forward_equals_ref(name):
    rng = np.random.default_rng(7)
    params = init_params(name, 11)
    x = jnp.asarray(rng.uniform(0, 1, SHAPES[name]), jnp.float32)
    y_ref = forward(params, x, name)
    y_pal = forward_pallas(params, x, name)
    np.testing.assert_allclose(y_pal, y_ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", list(ARCHS))
def test_classifier_heads_are_distributions(name):
    if name == "robot":
        pytest.skip("detector head is not a softmax")
    rng = np.random.default_rng(3)
    params = init_params(name, 5)
    x = jnp.asarray(rng.uniform(0, 1, SHAPES[name]), jnp.float32)
    y = forward(params, x, name).reshape(-1)
    np.testing.assert_allclose(float(jnp.sum(y)), 1.0, rtol=1e-5)


def test_fold_bn_removes_bn_and_dropout():
    params = init_params("robot", 1)
    folded, spec = fold_bn_params(params, "robot")
    kinds = [k for k, _ in spec]
    assert "batchnorm" not in kinds
    assert "dropout" not in kinds
    # all leaky_relus fused into convs
    assert all(k in ("conv", "maxpool") for k in kinds), kinds


def test_fold_bn_preserves_numerics_with_nontrivial_stats():
    rng = np.random.default_rng(2)
    params = init_params("robot", 3)
    # perturb BN stats away from identity
    for p, (kind, _) in zip(params, ARCHS["robot"]["layers"]):
        if kind == "batchnorm" and p is not None:
            c = p["gamma"].shape[0]
            p["gamma"] = jnp.asarray(rng.uniform(0.5, 1.5, c), jnp.float32)
            p["beta"] = jnp.asarray(rng.uniform(-0.3, 0.3, c), jnp.float32)
            p["mean"] = jnp.asarray(rng.uniform(-0.5, 0.5, c), jnp.float32)
            p["var"] = jnp.asarray(rng.uniform(0.3, 1.2, c), jnp.float32)
    x = jnp.asarray(rng.uniform(0, 1, SHAPES["robot"]), jnp.float32)
    y_ref = forward(params, x, "robot")
    y_pal = forward_pallas(params, x, "robot")
    np.testing.assert_allclose(y_pal, y_ref, rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# export format
# --------------------------------------------------------------------------


def test_arch_json_is_valid_and_complete():
    for name in ARCHS:
        doc = json.loads(arch_json(name))
        assert doc["name"] == name
        assert len(doc["layers"]) == len(ARCHS[name]["layers"])
        assert len(doc["input"]) == 3


def test_nncgw_round_trip(tmp_path):
    params = init_params("ball", 9)
    recs = weight_records("ball", params)
    path = os.path.join(tmp_path, "ball.nncgw")
    write_nncgw(path, recs)
    back = read_nncgw(path)
    assert set(back) == {n for n, _ in recs}
    for n, arr in recs:
        np.testing.assert_array_equal(back[n], np.asarray(arr))


def test_weight_records_cover_all_parametric_layers():
    params = init_params("robot", 0)
    names = {n for n, _ in weight_records("robot", params)}
    # 5 convs (w+b) + 5 batchnorms (4 each) = 30 records
    assert len(names) == 5 * 2 + 5 * 4


# --------------------------------------------------------------------------
# datasets
# --------------------------------------------------------------------------


def test_ball_batch_shapes_and_determinism():
    a = datasets.ball_batch(8, np.random.default_rng(1))
    b = datasets.ball_batch(8, np.random.default_rng(1))
    assert a[0].shape == (8, 16, 16, 1)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    assert set(np.unique(a[1])).issubset({0, 1})


def test_pedestrian_batch_positive_is_darker_in_center():
    xs, ys = datasets.pedestrian_batch(64, np.random.default_rng(2))
    pos = xs[ys == 1][..., 0][:, 10:20, 7:12].mean()
    neg = xs[ys == 0][..., 0][:, 10:20, 7:12].mean()
    assert pos < neg, (pos, neg)


def test_robot_targets_are_decodable():
    rng = np.random.default_rng(3)
    img, boxes = datasets.robot_scene(rng)
    assert img.shape == (60, 80, 3)
    assert boxes
    t, om, bm = datasets.robot_target(boxes)
    assert t.shape == (15, 20, 20)
    # objectness supervised everywhere; boxes only at positives
    assert om.sum() == 15 * 20 * 4
    assert bm.sum() == 4 * len({(int((y + h / 2) // 4), int((x + w / 2) // 4)) for (y, x, h, w) in boxes}) or bm.sum() > 0


def test_calibrate_bn_aligns_inference_with_training_stats():
    """After calibration, inference-mode forward (stored stats) must track
    train-mode forward (batch stats) on the calibration distribution."""
    import jax.numpy as jnp
    from compile.model import calibrate_bn

    rng = np.random.default_rng(11)
    params = init_params("robot", 4)
    xs = rng.uniform(0, 1, (8, 60, 80, 3)).astype(np.float32)
    calibrated = calibrate_bn(params, "robot", xs)
    x = jnp.asarray(xs[0])
    y_train = forward(params, x, "robot", train=True)
    y_uncal = forward(params, x, "robot", train=False)
    y_cal = forward(calibrated, x, "robot", train=False)
    err_uncal = float(jnp.abs(y_train - y_uncal).max())
    err_cal = float(jnp.abs(y_train - y_cal).max())
    assert err_cal < err_uncal, (err_cal, err_uncal)
    assert err_cal < 2.0, err_cal  # same scale as batch-stat outputs
