"""Export trained parameters to the Rust interchange formats.

Writes ``<out>/<name>.json`` (architecture, the schema of
``rust/src/model/mod.rs``) and ``<out>/<name>.nncgw`` (binary weights, the
format of ``rust/src/model/weights.rs``). Record names are ``layer{i}.*``
with ``i`` indexing the spec's layer list — identical to the Rust zoo's
layer ordering.

``python -m compile.export --init`` writes seeded Glorot weights without
training, so ``make artifacts`` works before ``make train`` has run.
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import numpy as np

from .model import ARCHS, init_params

MAGIC = b"NNCGW1\x00\x00"


def arch_json(name: str) -> str:
    """Architecture JSON matching rust/src/model schema."""
    spec = ARCHS[name]
    layers = []
    for kind, cfg in spec["layers"]:
        if kind == "conv":
            layers.append(
                {
                    "kind": "conv2d",
                    "c_out": cfg["c_out"],
                    "kernel": list(cfg["kernel"]),
                    "stride": list(cfg["stride"]),
                    "padding": cfg["padding"],
                    "activation": "none",
                }
            )
        elif kind == "maxpool":
            layers.append({"kind": "maxpool", "pool": list(cfg["pool"]), "stride": list(cfg["stride"])})
        elif kind == "relu":
            layers.append({"kind": "relu"})
        elif kind == "leaky_relu":
            layers.append({"kind": "leaky_relu", "alpha": cfg["alpha"]})
        elif kind == "softmax":
            layers.append({"kind": "softmax"})
        elif kind == "batchnorm":
            layers.append({"kind": "batchnorm", "channels": cfg["channels"], "epsilon": 1e-3})
        elif kind == "dropout":
            layers.append({"kind": "dropout", "rate": cfg["rate"]})
        else:
            raise ValueError(kind)
    return json.dumps({"name": name, "input": list(spec["input"]), "layers": layers})


def weight_records(name: str, params) -> list[tuple[str, np.ndarray]]:
    """Named tensors in Rust loader order."""
    records = []
    for i, (kind, _cfg) in enumerate(ARCHS[name]["layers"]):
        p = params[i]
        if kind == "conv":
            records.append((f"layer{i}.weights", np.asarray(p["w"], np.float32)))
            records.append((f"layer{i}.bias", np.asarray(p["b"], np.float32)))
        elif kind == "batchnorm":
            records.append((f"layer{i}.gamma", np.asarray(p["gamma"], np.float32)))
            records.append((f"layer{i}.beta", np.asarray(p["beta"], np.float32)))
            records.append((f"layer{i}.mean", np.asarray(p["mean"], np.float32)))
            records.append((f"layer{i}.variance", np.asarray(p["var"], np.float32)))
    return records


def write_nncgw(path: str, records: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(records)))
        for name, arr in records:
            arr = np.ascontiguousarray(arr, np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_nncgw(path: str) -> dict[str, np.ndarray]:
    """Read the binary format back (round-trip tests)."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == MAGIC, "bad magic"
    pos = 8
    (count,) = struct.unpack_from("<I", data, pos)
    pos += 4
    out = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<I", data, pos)
        pos += 4
        name = data[pos : pos + nlen].decode()
        pos += nlen
        (rank,) = struct.unpack_from("<I", data, pos)
        pos += 4
        dims = struct.unpack_from(f"<{rank}I", data, pos)
        pos += 4 * rank
        n = int(np.prod(dims)) if rank else 1
        arr = np.frombuffer(data, np.float32, n, pos).reshape(dims)
        pos += 4 * n
        out[name] = arr
    assert pos == len(data), "trailing bytes"
    return out


def export_model(name: str, params, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        f.write(arch_json(name))
    write_nncgw(os.path.join(out_dir, f"{name}.nncgw"), weight_records(name, params))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../models")
    ap.add_argument("--init", action="store_true", help="write seeded untrained weights")
    ap.add_argument("--only-missing", action="store_true", help="skip models that already have files")
    ap.add_argument("--models", nargs="*", default=list(ARCHS))
    args = ap.parse_args()
    for name in args.models:
        stem = os.path.join(args.out, name)
        if args.only_missing and os.path.exists(stem + ".json") and os.path.exists(stem + ".nncgw"):
            print(f"{name}: exists, skipping")
            continue
        params = init_params(name, seed=1234)
        export_model(name, params, args.out)
        print(f"{name}: wrote {stem}.json / .nncgw ({'untrained' if args.init else 'init'} weights)")


if __name__ == "__main__":
    main()
