"""Pallas direct-convolution kernel (Layer 1).

TPU adaptation of the paper's NNCG convolution (DESIGN.md
SSHardware-Adaptation):

* The zero-padded input x-hat (Eq. 1) is materialized once outside the
  kernel (``jnp.pad``), exactly like the generated C's ``nncg_pad`` buffer,
  so the kernel body is branch-free.
* The grid runs over **output rows**; each program instance computes one
  (w_out, c_out) row block -- the BlockSpec analogue of the paper's "keep
  the two outermost loops" unroll level.
* Kernel taps (n, m) are Python loops, unrolled at trace time because the
  kernel extent is a compile-time constant -- principle P1.
* The inner reduction is ``(w_out, c_in) @ (c_in, c_out)`` with channels
  minor, mapping the paper's SIMD-over-output-channels (P4) onto the
  MXU/VPU lane dimension.
* Activations are fused on the accumulator via ``jnp.where``/``maximum``
  (P2: predication instead of branches).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated analytically in
EXPERIMENTS.md SSPerf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _same_pad(in_size: int, k: int, s: int) -> tuple[int, int]:
    """Keras 'same' padding split (Eq. 1): returns (before, after)."""
    out = -(-in_size // s)  # ceil
    total = max((out - 1) * s + k - in_size, 0)
    return total // 2, total - total // 2


def _conv_row_kernel(x_ref, w_ref, b_ref, o_ref, *, h_k, w_k, sh, sw, w_out, act, alpha):
    """One grid step: compute output row ``i`` for all channels."""
    i = pl.program_id(0)
    x = x_ref[...]  # (ph, pw, c_in) -- whole padded input resident in VMEM
    w = w_ref[...]  # (h_k, w_k, c_in, c_out)
    b = b_ref[...]  # (c_out,)
    c_out = b.shape[0]
    acc = jnp.zeros((w_out, c_out), jnp.float32) + b[None, :]
    for n in range(h_k):  # P1: unrolled at trace time
        row = jax.lax.dynamic_slice_in_dim(x, i * sh + n, 1, axis=0)[0]  # (pw, c_in)
        for m in range(w_k):
            # strided column gather: inputs for all w_out outputs at tap m
            cols = jax.lax.slice_in_dim(row, m, m + sw * (w_out - 1) + 1, sw, axis=0)
            acc = acc + cols.astype(jnp.float32) @ w[n, m].astype(jnp.float32)  # P4: MXU matmul
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)  # P2: predication
    elif act == "leaky_relu":
        acc = jnp.maximum(acc, alpha * acc)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "act", "alpha", "interpret")
)
def conv2d_pallas(x, w, b, stride=(1, 1), padding="valid", act="none", alpha=0.1, interpret=True):
    """Pallas conv over one HWC image; numerically equal to ``ref.conv2d``
    (+ fused activation).

    x: (h, w, c_in) f32; w: (hk, wk, c_in, c_out); b: (c_out,).
    """
    h_in, w_in, c_in = x.shape
    h_k, w_k, _, c_out = w.shape
    sh, sw = stride
    if padding == "same":
        (pt, pb) = _same_pad(h_in, h_k, sh)
        (pl_, pr) = _same_pad(w_in, w_k, sw)
        x = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))  # Eq. 1 materialized
    elif padding != "valid":
        raise ValueError(f"unknown padding {padding!r}")
    ph, pw, _ = x.shape
    h_out = (ph - h_k) // sh + 1
    w_out = (pw - w_k) // sw + 1

    kernel = functools.partial(
        _conv_row_kernel, h_k=h_k, w_k=w_k, sh=sh, sw=sw, w_out=w_out, act=act, alpha=alpha
    )
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            # whole padded input per step: these nets are tiny (<< VMEM)
            pl.BlockSpec((ph, pw, c_in), lambda i: (0, 0, 0)),
            pl.BlockSpec((h_k, w_k, c_in, c_out), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((c_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, w_out, c_out), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out, c_out), x.dtype),
        interpret=interpret,
    )(x, w, b)


def vmem_report(x_shape, w_shape, stride=(1, 1), padding="valid"):
    """Analytic VMEM footprint of one grid step, for the perf analysis
    (interpret mode has no real VMEM; see EXPERIMENTS.md SSPerf)."""
    h_in, w_in, c_in = x_shape
    h_k, w_k, _, c_out = w_shape
    if padding == "same":
        ph = h_in + sum(_same_pad(h_in, h_k, stride[0]))
        pw = w_in + sum(_same_pad(w_in, w_k, stride[1]))
    else:
        ph, pw = h_in, w_in
    w_out = (pw - w_k) // stride[1] + 1
    bytes_in = ph * pw * c_in * 4
    bytes_w = h_k * w_k * c_in * c_out * 4
    bytes_out = w_out * c_out * 4
    total = bytes_in + bytes_w + bytes_out
    return {
        "input_bytes": bytes_in,
        "weight_bytes": bytes_w,
        "out_row_bytes": bytes_out,
        "total_bytes": total,
        "vmem_fraction_16MiB": total / (16 * 1024 * 1024),
        "macs_per_step": w_out * c_out * h_k * w_k * c_in,
        "lane_utilization_cout": min(c_out / 128.0, 1.0),
    }
