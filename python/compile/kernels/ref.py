"""Pure-jnp reference implementations (the correctness oracle).

Direct transcriptions of the paper's Eq. 1-6 using stock jax ops. Every
Pallas kernel in this package is pytest-verified against these, and the
trainer differentiates through them (they are cheap and jit-friendly).

Layout conventions match the Rust side: activations HWC (channel-minor),
conv weights HWIO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d(x, w, b, stride=(1, 1), padding="valid"):
    """2-d convolution over one HWC image (no batch dim), Eq. 2.

    x: (h, w, c_in); w: (hk, wk, c_in, c_out); b: (c_out,).
    padding: "same" (Keras semantics, Eq. 1) or "valid".
    """
    lhs = x[None]  # NHWC
    out = jax.lax.conv_general_dilated(
        lhs,
        w,
        window_strides=stride,
        padding=padding.upper(),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0] + b


def maxpool2d(x, pool=(2, 2), stride=(2, 2)):
    """Max pooling, Eq. 3 (valid windows only)."""
    out = jax.lax.reduce_window(
        x[None],
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, pool[0], pool[1], 1),
        window_strides=(1, stride[0], stride[1], 1),
        padding="VALID",
    )
    return out[0]


def relu(x):
    """Eq. 4."""
    return jnp.maximum(x, 0.0)


def leaky_relu(x, alpha=0.1):
    """Eq. 5 — expressed as a predicated select (the paper's cmov)."""
    return jnp.where(x > 0, x, alpha * x)


def softmax(x):
    """Numerically-stable softmax over the flattened tensor."""
    flat = x.reshape(-1)
    m = jnp.max(flat)
    e = jnp.exp(flat - m)
    return (e / jnp.sum(e)).reshape(x.shape)


def batchnorm(x, gamma, beta, mean, var, eps=1e-3):
    """Inference-mode batch normalization, Eq. 6 with learned affine."""
    scale = gamma / jnp.sqrt(var + eps)
    return x * scale + (beta - mean * scale)


def fold_batchnorm(w, b, gamma, beta, mean, var, eps=1e-3):
    """Fold BN into the preceding conv (paper §II-B.4).

    Returns (w', b') with w'[..., k] = w[..., k] * s_k and
    b' = s * b + (beta - mean * s).
    """
    scale = gamma / jnp.sqrt(var + eps)
    return w * scale, b * scale + (beta - mean * scale)
