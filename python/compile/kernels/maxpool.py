"""Pallas max-pooling kernel (Layer 1), Eq. 3.

Same schedule shape as the conv kernel: grid over output rows, window taps
unrolled at trace time, channel-minor maxima on the VPU lanes (the paper's
SSE ``maxps`` over channel groups, P2+P4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_row_kernel(x_ref, o_ref, *, pool, stride, w_out):
    i = pl.program_id(0)
    x = x_ref[...]  # (h_in, w_in, c)
    acc = None
    for n in range(pool[0]):  # unrolled taps
        row = jax.lax.dynamic_slice_in_dim(x, i * stride[0] + n, 1, axis=0)[0]  # (w_in, c)
        for m in range(pool[1]):
            cols = jax.lax.slice_in_dim(row, m, m + stride[1] * (w_out - 1) + 1, stride[1], axis=0)
            acc = cols if acc is None else jnp.maximum(acc, cols)  # P2: predicated max
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("pool", "stride", "interpret"))
def maxpool2d_pallas(x, pool=(2, 2), stride=(2, 2), interpret=True):
    """Pallas max-pool over one HWC image; equals ``ref.maxpool2d``."""
    h_in, w_in, c = x.shape
    h_out = (h_in - pool[0]) // stride[0] + 1
    w_out = (w_in - pool[1]) // stride[1] + 1
    kernel = functools.partial(_pool_row_kernel, pool=pool, stride=stride, w_out=w_out)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[pl.BlockSpec((h_in, w_in, c), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((1, w_out, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out, c), x.dtype),
        interpret=interpret,
    )(x)
