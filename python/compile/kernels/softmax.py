"""Pallas softmax kernel (Layer 1).

Single-program kernel (the classifier heads are 1x1x2); numerically stable
via max subtraction, like both the reference and the generated C.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    flat = x.reshape(-1)
    m = jnp.max(flat)
    e = jnp.exp(flat - m)
    o_ref[...] = (e / jnp.sum(e)).reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax_pallas(x, interpret=True):
    """Pallas softmax over the flattened tensor; equals ``ref.softmax``."""
    return pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
