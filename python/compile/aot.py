"""AOT lowering: JAX model (+ Pallas kernels) -> HLO text artifacts.

For every model, bakes the exported weights (``models/<name>.nncgw``, or
seeded init if absent) into the computation as constants — the paper's
principle P3 at the HLO level — and lowers

    f(x_flat: f32[in_numel]) -> (f32[out_numel],)

to HLO **text** at ``artifacts/<name>.hlo.txt``. The Rust runtime
(``rust/src/runtime``) loads the text, compiles it once on the PJRT CPU
client, and executes it on the request path; Python is never loaded again.

HLO text, not ``.serialize()``: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .export import read_nncgw
from .model import ARCHS, forward_pallas, init_params


def load_params(name: str, models_dir: str):
    """Exported weights if present, else seeded init (matching export.py)."""
    path = os.path.join(models_dir, f"{name}.nncgw")
    if not os.path.exists(path):
        return init_params(name, seed=1234)
    recs = read_nncgw(path)
    params = []
    for i, (kind, _cfg) in enumerate(ARCHS[name]["layers"]):
        if kind == "conv":
            params.append(
                {"w": jnp.asarray(recs[f"layer{i}.weights"]), "b": jnp.asarray(recs[f"layer{i}.bias"])}
            )
        elif kind == "batchnorm":
            params.append(
                {
                    "gamma": jnp.asarray(recs[f"layer{i}.gamma"]),
                    "beta": jnp.asarray(recs[f"layer{i}.beta"]),
                    "mean": jnp.asarray(recs[f"layer{i}.mean"]),
                    "var": jnp.asarray(recs[f"layer{i}.variance"]),
                }
            )
        else:
            params.append(None)
    return params


def flat_fn(name: str, params, use_pallas: bool = True):
    """The exported computation: flat f32 in, 1-tuple flat f32 out."""
    spec = ARCHS[name]
    in_shape = spec["input"]

    def f(x_flat):
        x = x_flat.reshape(in_shape)
        if use_pallas:
            y = forward_pallas(params, x, name, interpret=True)
        else:
            from .model import forward

            y = forward(params, x, name)
        return (y.reshape(-1),)

    return f, int(np.prod(in_shape))


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe route).

    ``as_hlo_text(True)`` = ``print_large_constants=True``: the default
    printer elides big weight tensors as ``constant({...})``, which the old
    text parser silently reads back as *zeros* — the baked weights (P3!)
    must be printed in full.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_model(name: str, models_dir: str, use_pallas: bool = True) -> str:
    params = load_params(name, models_dir)
    f, in_numel = flat_fn(name, params, use_pallas)
    spec = jax.ShapeDtypeStruct((in_numel,), jnp.float32)
    return to_hlo_text(jax.jit(f).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models-dir", default="../models")
    ap.add_argument("--models", nargs="*", default=list(ARCHS))
    ap.add_argument("--no-pallas", action="store_true", help="lower the pure-jnp path instead")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.models:
        text = lower_model(name, args.models_dir, use_pallas=not args.no_pallas)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"{name}: wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
