"""Training (build-time only): the paper's three CNNs on the synthetic
datasets, with a hand-rolled Adam (optax is not in the offline env).

* ball / pedestrian: binary cross-entropy on the softmax head.
  Paper accuracies on the real corpora: 99.975% / 99.02%; EXPERIMENTS.md
  records what we reach on the synthetic stand-ins.
* robot: YOLO-style loss (masked MSE on box regression + objectness
  logits) against the targets of ``datasets.robot_target``.

Run via ``make train``; writes ``models/<name>.{json,nncgw}`` and appends
the loss curves to ``models/train_log_<name>.txt``.
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .export import export_model
from .model import ARCHS, forward, init_params

# --------------------------------------------------------------------------
# Hand-rolled Adam
# --------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps), params, m, v
    )
    return new_params, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def classifier_loss(params, xs, ys, name):
    """Mean NLL of the softmax head over a batch (classes on channel dim)."""

    def one(x, y):
        p = forward(params, x, name, train=True).reshape(-1)
        return -jnp.log(p[y] + 1e-9)

    return jnp.mean(jax.vmap(one)(xs, ys))


def yolo_loss(params, xs, targets, obj_masks, box_masks, name):
    """Masked MSE on raw head values (targets are pre-encoded logits).

    Positive objectness cells are ~1:1200 against negatives, so the two
    populations are normalized separately (YOLO's no-object weighting);
    positive cells are identified by their target logit being the
    logit(0.95) encoding rather than the -4 background fill.
    """

    def one(x, t, om, bm):
        h = forward(params, x, name, train=True)
        pos = om * (t > 0).astype(jnp.float32)  # positive objectness channels
        neg = om * (t <= 0).astype(jnp.float32)
        obj_pos = jnp.sum(pos * (h - t) ** 2) / (jnp.sum(pos) + 1e-9)
        obj_neg = jnp.sum(neg * (h - t) ** 2) / (jnp.sum(neg) + 1e-9)
        box = jnp.sum(bm * (h - t) ** 2) / (jnp.sum(bm) + 1e-9)
        return 2.0 * obj_pos + 0.5 * obj_neg + 5.0 * box

    return jnp.mean(jax.vmap(one)(xs, targets, obj_masks, box_masks))


# --------------------------------------------------------------------------
# Training loops
# --------------------------------------------------------------------------


def train_classifier(name, steps, batch, lr, seed, log):
    rng = np.random.default_rng(seed)
    params = init_params(name, seed)
    state = adam_init(params)
    gen = {"ball": datasets.ball_batch, "pedestrian": datasets.pedestrian_batch}[name]

    @jax.jit
    def step(params, state, xs, ys):
        loss, grads = jax.value_and_grad(classifier_loss)(params, xs, ys, name)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    t0 = time.time()
    for i in range(steps):
        xs, ys = gen(batch, rng)
        params, state, loss = step(params, state, jnp.asarray(xs), jnp.asarray(ys))
        if i % 20 == 0 or i == steps - 1:
            log(f"step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")

    # held-out accuracy
    xs, ys = gen(512, rng)
    acc = accuracy(params, jnp.asarray(xs), np.asarray(ys), name)
    log(f"final: steps={steps} eval_accuracy={acc:.4%}")
    return params, acc


def accuracy(params, xs, ys, name):
    @jax.jit
    def probs(x):
        return forward(params, x, name).reshape(-1)

    preds = np.array([int(jnp.argmax(probs(x))) for x in xs])
    return float((preds == ys).mean())


def train_robot(steps, batch, lr, seed, log):
    name = "robot"
    rng = np.random.default_rng(seed)
    params = init_params(name, seed)
    state = adam_init(params)

    @jax.jit
    def step(params, state, xs, ts, oms, bms):
        loss, grads = jax.value_and_grad(yolo_loss)(params, xs, ts, oms, bms, name)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    t0 = time.time()
    first = last = None
    for i in range(steps):
        xs, ts, oms, bms = datasets.robot_batch(batch, rng)
        params, state, loss = step(params, state, jnp.asarray(xs), jnp.asarray(ts), jnp.asarray(oms), jnp.asarray(bms))
        if first is None:
            first = float(loss)
        last = float(loss)
        if i % 20 == 0 or i == steps - 1:
            log(f"step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    # Inference uses stored BN statistics, training used batch statistics:
    # calibrate the stored stats on a held-out set before export.
    from .model import calibrate_bn

    xs, _, _, _ = datasets.robot_batch(32, rng)
    params = calibrate_bn(params, name, xs)
    log(f"final: steps={steps} loss {first:.4f} -> {last:.4f} (BN calibrated on 32 scenes)")
    return params, last


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../models")
    ap.add_argument("--models", nargs="*", default=list(ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    for name in args.models:
        log_path = os.path.join(args.out, f"train_log_{name}.txt")
        with open(log_path, "w") as logf:

            def log(msg, _f=logf, _n=name):
                line = f"[{_n}] {msg}"
                print(line, flush=True)
                _f.write(line + "\n")

            if name in ("ball", "pedestrian"):
                params, metric = train_classifier(name, args.steps, args.batch, args.lr, args.seed, log)
            else:
                params, metric = train_robot(args.steps, args.batch, args.lr, args.seed, log)
            export_model(name, params, args.out)
            log(f"exported to {os.path.join(args.out, name)}.json/.nncgw")


if __name__ == "__main__":
    main()
