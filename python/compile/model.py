"""Layer 2: the paper's CNNs (Tables I-III) as JAX functions.

Architecture specs mirror ``rust/src/graph/zoo.rs`` exactly (same layer
order, shapes and HWC/HWIO layouts), so weights exported from here load
directly into the Rust side, and the AOT artifacts compute the same
function as the generated C.

Two forward paths over the same parameters:

* ``forward(params, x, spec)``            — pure-jnp reference (trainable).
* ``forward_pallas(params, x, spec)``     — calls the Layer-1 Pallas kernels
  (conv/maxpool/softmax), used for the AOT export. pytest asserts the two
  are numerically equal.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from .kernels import ref
from .kernels.conv2d import conv2d_pallas
from .kernels.maxpool import maxpool2d_pallas
from .kernels.softmax import softmax_pallas

# ---------------------------------------------------------------------------
# Architecture specs (paper Tables I-III). Input shapes are HWC.
# ---------------------------------------------------------------------------

ARCHS = {
    # Table I: ball classifier, 16x16 grayscale.
    "ball": {
        "input": (16, 16, 1),
        "layers": [
            ("conv", dict(c_out=8, kernel=(5, 5), stride=(2, 2), padding="same")),
            ("relu", {}),
            ("maxpool", dict(pool=(2, 2), stride=(2, 2))),
            ("conv", dict(c_out=12, kernel=(3, 3), stride=(1, 1), padding="valid")),
            ("relu", {}),
            ("conv", dict(c_out=2, kernel=(2, 2), stride=(1, 1), padding="valid")),
            ("softmax", {}),
        ],
    },
    # Table II: pedestrian classifier, 18x36 (HWC: [36, 18, 1]).
    "pedestrian": {
        "input": (36, 18, 1),
        "layers": [
            ("conv", dict(c_out=12, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("relu", {}),
            ("maxpool", dict(pool=(2, 2), stride=(2, 2))),
            ("conv", dict(c_out=32, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("leaky_relu", dict(alpha=0.1)),
            ("maxpool", dict(pool=(2, 2), stride=(2, 2))),
            ("conv", dict(c_out=64, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("leaky_relu", dict(alpha=0.1)),
            ("maxpool", dict(pool=(2, 2), stride=(2, 2))),
            ("dropout", dict(rate=0.3)),
            ("conv", dict(c_out=2, kernel=(4, 2), stride=(1, 1), padding="valid")),
            ("softmax", {}),
        ],
    },
    # Table III: robot detector, 80x60 RGB (HWC: [60, 80, 3]).
    "robot": {
        "input": (60, 80, 3),
        "layers": [
            ("conv", dict(c_out=8, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("batchnorm", dict(channels=8)),
            ("leaky_relu", dict(alpha=0.1)),
            ("maxpool", dict(pool=(2, 2), stride=(2, 2))),
            ("conv", dict(c_out=12, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("batchnorm", dict(channels=12)),
            ("leaky_relu", dict(alpha=0.1)),
            ("conv", dict(c_out=8, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("batchnorm", dict(channels=8)),
            ("leaky_relu", dict(alpha=0.1)),
            ("maxpool", dict(pool=(2, 2), stride=(2, 2))),
            ("conv", dict(c_out=16, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("batchnorm", dict(channels=16)),
            ("leaky_relu", dict(alpha=0.1)),
            ("conv", dict(c_out=20, kernel=(3, 3), stride=(1, 1), padding="same")),
            ("batchnorm", dict(channels=20)),
            ("leaky_relu", dict(alpha=0.1)),
        ],
    },
}


def init_params(name: str, seed: int = 0):
    """Glorot-uniform parameters for an architecture, as a list aligned
    with the spec's layers (non-parametric layers get ``None``)."""
    spec = ARCHS[name]
    rng = np.random.default_rng(seed)
    params = []
    c_in = spec["input"][2]
    for kind, cfg in spec["layers"]:
        if kind == "conv":
            hk, wk = cfg["kernel"]
            c_out = cfg["c_out"]
            fan_in, fan_out = hk * wk * c_in, hk * wk * c_out
            limit = np.sqrt(6.0 / (fan_in + fan_out))
            params.append(
                {
                    "w": jnp.asarray(rng.uniform(-limit, limit, (hk, wk, c_in, c_out)), jnp.float32),
                    "b": jnp.zeros((c_out,), jnp.float32),
                }
            )
            c_in = c_out
        elif kind == "batchnorm":
            c = cfg["channels"]
            params.append(
                {
                    "gamma": jnp.ones((c,), jnp.float32),
                    "beta": jnp.zeros((c,), jnp.float32),
                    "mean": jnp.zeros((c,), jnp.float32),
                    "var": jnp.ones((c,), jnp.float32),
                }
            )
        else:
            params.append(None)
    return params


def forward(params, x, name: str, train: bool = False):
    """Reference forward pass (pure jnp). With ``train=True`` BatchNorm
    uses batch statistics computed over the spatial dims of this sample and
    dropout stays identity (the synthetic task does not need it)."""
    spec = ARCHS[name]
    for p, (kind, cfg) in zip(params, spec["layers"]):
        if kind == "conv":
            x = ref.conv2d(x, p["w"], p["b"], cfg["stride"], cfg["padding"])
        elif kind == "relu":
            x = ref.relu(x)
        elif kind == "leaky_relu":
            x = ref.leaky_relu(x, cfg["alpha"])
        elif kind == "maxpool":
            x = ref.maxpool2d(x, cfg["pool"], cfg["stride"])
        elif kind == "softmax":
            x = ref.softmax(x)
        elif kind == "batchnorm":
            if train:
                mu = jnp.mean(x, axis=(0, 1))
                var = jnp.var(x, axis=(0, 1))
                x = ref.batchnorm(x, p["gamma"], p["beta"], mu, var)
            else:
                x = ref.batchnorm(x, p["gamma"], p["beta"], p["mean"], p["var"])
        elif kind == "dropout":
            pass  # inference no-op (paper: dropout only regularizes training)
        else:
            raise ValueError(f"unknown layer kind {kind!r}")
    return x


def forward_pallas(params, x, name: str, interpret: bool = True):
    """Forward pass through the Layer-1 Pallas kernels. BatchNorm is folded
    into the preceding conv first (paper §II-B.4) so the kernel sequence
    matches the generated C exactly."""
    folded, spec = fold_bn_params(params, name)
    for p, (kind, cfg) in zip(folded, spec):
        if kind == "conv":
            x = conv2d_pallas(
                x,
                p["w"],
                p["b"],
                stride=cfg["stride"],
                padding=cfg["padding"],
                act=cfg.get("fused_act", "none"),
                alpha=cfg.get("alpha", 0.1),
                interpret=interpret,
            )
        elif kind == "maxpool":
            x = maxpool2d_pallas(x, cfg["pool"], cfg["stride"], interpret=interpret)
        elif kind == "softmax":
            x = softmax_pallas(x, interpret=interpret)
        elif kind == "relu":
            x = ref.relu(x)  # unfused standalone (after pool)
        elif kind == "leaky_relu":
            x = ref.leaky_relu(x, cfg["alpha"])
        else:
            raise ValueError(f"unexpected layer kind after folding: {kind!r}")
    return x


def fold_bn_params(params, name: str):
    """Fold BN into convs and fuse directly-following activations, mirroring
    ``rust/src/passes``. Returns (folded_params, folded_spec) where the spec
    is a list of (kind, cfg) with dropout removed and activations fused into
    ``cfg['fused_act']`` where possible."""
    spec = ARCHS[name]["layers"]
    out_params, out_spec = [], []
    i = 0
    while i < len(spec):
        kind, cfg = spec[i]
        p = params[i]
        if kind == "conv":
            w, b = p["w"], p["b"]
            cfg = dict(cfg)
            j = i + 1
            # fold a following batchnorm
            if j < len(spec) and spec[j][0] == "batchnorm":
                bn = params[j]
                w, b = ref.fold_batchnorm(w, b, bn["gamma"], bn["beta"], bn["mean"], bn["var"])
                j += 1
            # fuse a following activation
            if j < len(spec) and spec[j][0] in ("relu", "leaky_relu"):
                cfg["fused_act"] = spec[j][0]
                cfg["alpha"] = spec[j][1].get("alpha", 0.1)
                j += 1
            out_params.append({"w": w, "b": b})
            out_spec.append(("conv", cfg))
            i = j
        elif kind == "dropout":
            i += 1
        elif kind == "batchnorm":
            raise ValueError("BatchNorm not preceded by conv cannot be folded")
        else:
            out_params.append(None)
            out_spec.append((kind, cfg))
            i += 1
    return out_params, out_spec


def calibrate_bn(params, name: str, xs):
    """Estimate BatchNorm running statistics from a calibration set.

    Training normalizes with per-batch statistics; inference (and every
    exported artifact) uses the stored mean/var. Walks the net layer by
    layer over `xs` (n, h, w, c), using batch statistics *up to* each BN —
    matching what the layer saw during training — and writes the pooled
    mean/var into the params. Returns the updated params.
    """
    import jax

    spec = ARCHS[name]
    out = [dict(p) if isinstance(p, dict) else None for p in params]
    x = jnp.asarray(xs)

    def batched(f):
        return jax.vmap(f)

    for i, (kind, cfg) in enumerate(spec["layers"]):
        p = out[i]
        if kind == "conv":
            x = batched(lambda im: ref.conv2d(im, p["w"], p["b"], cfg["stride"], cfg["padding"]))(x)
        elif kind == "relu":
            x = ref.relu(x)
        elif kind == "leaky_relu":
            x = ref.leaky_relu(x, cfg["alpha"])
        elif kind == "maxpool":
            x = batched(lambda im: ref.maxpool2d(im, cfg["pool"], cfg["stride"]))(x)
        elif kind == "softmax":
            x = batched(ref.softmax)(x)
        elif kind == "batchnorm":
            mu = jnp.mean(x, axis=(0, 1, 2))
            var = jnp.var(x, axis=(0, 1, 2))
            out[i] = dict(p, mean=mu, var=var)
            x = ref.batchnorm(x, p["gamma"], p["beta"], mu, var)
        elif kind == "dropout":
            pass
        else:
            raise ValueError(kind)
    return out


def output_shape(name: str):
    """Static output shape of a model (via an abstract trace)."""
    import jax

    spec = ARCHS[name]
    x = jax.ShapeDtypeStruct(spec["input"], jnp.float32)
    params = init_params(name, 0)
    return jax.eval_shape(lambda p, xx: forward(p, xx, name), params, x).shape
