"""Synthetic datasets standing in for the paper's corpora.

Paper datasets (not available offline):
* ball: 455,107 RoboCup candidate patches (125,615 positives), 16x16.
* pedestrian: Daimler benchmark, 49,000 crops (24,000 positives), 18x36.
* robot: RoboCup scenes for the YOLO-style detector.

These generators produce structurally analogous data — high-contrast
ball-like discs vs field clutter, dark pedestrian silhouettes vs street
texture, rendered soccer scenes with robot boxes — mirroring the Rust
renderer (``rust/src/vision/render.rs``). Inference *latency*, the paper's
measured quantity, is independent of the pixels; the datasets exist to
prove the train -> export -> codegen -> deploy pipeline end to end with
honest accuracy numbers on a learnable task.
"""

from __future__ import annotations

import numpy as np


def ball_batch(n: int, rng: np.random.Generator):
    """(x, y): x (n,16,16,1) f32 in [0,1]; y (n,) int {0: no-ball, 1: ball}."""
    xs = np.empty((n, 16, 16, 1), np.float32)
    ys = rng.integers(0, 2, n)
    for i in range(n):
        xs[i] = _ball_patch(bool(ys[i]), rng)
    return xs, ys.astype(np.int32)


def _ball_patch(positive: bool, rng: np.random.Generator):
    img = 0.3 + 0.15 * rng.random((16, 16, 1), np.float32)
    if positive:
        r = int(rng.integers(4, 7))
        cy, cx = 8 + int(rng.integers(-1, 2)), 8 + int(rng.integers(-1, 2))
        yy, xx = np.mgrid[0:16, 0:16]
        d = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
        disc = d <= r
        img[..., 0][disc] = 0.95 - 0.1 * (d[disc] / r)
        for _ in range(3):  # dark spots
            a = rng.random() * 2 * np.pi
            rr = rng.random() * 0.6 * r
            sy, sx = cy + rr * np.sin(a), cx + rr * np.cos(a)
            spot = np.sqrt((yy - sy) ** 2 + (xx - sx) ** 2) < 0.3 * r
            img[..., 0][spot & disc] = 0.15
    else:
        kind = rng.integers(0, 3)
        if kind == 0:  # field line
            row = int(rng.integers(0, 16))
            img[row, :, 0] = 0.8
        elif kind == 1:  # bright blob (robot limb)
            t, l = int(rng.integers(0, 8)), int(rng.integers(0, 8))
            img[t : t + 8, l : l + 4, 0] = 0.85
        # kind == 2: plain field
    return img


def pedestrian_batch(n: int, rng: np.random.Generator):
    """(x, y): x (n,36,18,1); y (n,) int {0: none, 1: pedestrian}."""
    xs = np.empty((n, 36, 18, 1), np.float32)
    ys = rng.integers(0, 2, n)
    for i in range(n):
        xs[i] = _pedestrian_patch(bool(ys[i]), rng)
    return xs, ys.astype(np.int32)


def _pedestrian_patch(positive: bool, rng: np.random.Generator):
    img = 0.4 + 0.2 * rng.random((36, 18, 1), np.float32)
    if positive:
        cx = 9 + int(rng.integers(-1, 2))
        img[2:8, max(cx - 2, 0) : cx + 3, 0] = 0.12 + 0.05 * rng.random()  # head
        img[8:22, max(cx - 3, 0) : cx + 4, 0] = 0.15 + 0.05 * rng.random()  # torso
        img[22:34, max(cx - 2, 0) : cx, 0] = 0.18 + 0.05 * rng.random()  # legs
        img[22:34, cx + 1 : cx + 3, 0] = 0.18 + 0.05 * rng.random()
    elif rng.random() < 0.5:  # pole distractor
        col = int(rng.integers(0, 18))
        img[:, col, 0] = 0.2
    return img


# --- robot detector (YOLO-style targets) -----------------------------------

GRID_H, GRID_W, N_ANCHORS = 15, 20, 4
ANCHORS = [(0.8, 2.0), (1.2, 3.0), (1.8, 4.0), (2.5, 5.0)]  # (w, h) in cells
IMG_H, IMG_W = 60.0, 80.0


def robot_scene(rng: np.random.Generator):
    """One (60,80,3) scene and its list of ground-truth boxes
    (y, x, h, w in pixels)."""
    img = np.empty((60, 80, 3), np.float32)
    base = 0.35 + 0.1 * (np.arange(60, dtype=np.float32) / 60.0)[:, None]
    img[...] = (base + 0.03 * (rng.random((60, 80), np.float32) - 0.5))[..., None]
    img[30, :, :] = 0.8  # field line
    boxes = []
    for _ in range(int(rng.integers(1, 3))):
        rh, rw = int(rng.integers(16, 24)), int(rng.integers(6, 12))
        top = int(rng.integers(0, 60 - rh))
        left = int(rng.integers(0, 80 - rw))
        frac = (np.arange(top, top + rh, dtype=np.float32) - top) / rh
        body = 0.85 - 0.15 * np.abs(np.sin(frac * 6.0))
        img[top : top + rh, left : left + rw, :] = body[:, None, None]
        boxes.append((float(top), float(left), float(rh), float(rw)))
    return img, boxes


def robot_target(boxes):
    """Encode ground-truth boxes into a (15,20,20) YOLO target + mask.

    Returns (target, obj_mask, box_mask): target holds the regression
    values at responsible cells, obj_mask marks objectness channels
    (positive AND negative), box_mask marks box channels at positives only.
    Mirrors ``rust/src/vision/yolo.rs::encode_target``.
    """
    cell_h, cell_w = IMG_H / GRID_H, IMG_W / GRID_W
    target = np.zeros((GRID_H, GRID_W, N_ANCHORS * 5), np.float32)
    obj_mask = np.zeros_like(target)
    box_mask = np.zeros_like(target)
    # all objectness channels are supervised (negatives toward 0)
    for a in range(N_ANCHORS):
        obj_mask[:, :, a * 5 + 4] = 1.0
        target[:, :, a * 5 + 4] = -4.0  # logit of ~0.018
    logit = lambda p: float(np.log(np.clip(p, 1e-4, 1 - 1e-4) / (1 - np.clip(p, 1e-4, 1 - 1e-4))))
    for (y, x, h, w) in boxes:
        cy, cx = y + h / 2, x + w / 2
        gy, gx = min(int(cy / cell_h), GRID_H - 1), min(int(cx / cell_w), GRID_W - 1)
        best_a, best_iou = 0, -1.0
        for a, (aw, ah) in enumerate(ANCHORS):
            aw_px, ah_px = aw * cell_w, ah * cell_h
            inter = min(w, aw_px) * min(h, ah_px)
            union = w * h + aw_px * ah_px - inter
            if inter / union > best_iou:
                best_iou, best_a = inter / union, a
        aw, ah = ANCHORS[best_a]
        base = best_a * 5
        target[gy, gx, base + 0] = logit(cx / cell_w - gx)
        target[gy, gx, base + 1] = logit(cy / cell_h - gy)
        target[gy, gx, base + 2] = float(np.log(w / (aw * cell_w)))
        target[gy, gx, base + 3] = float(np.log(h / (ah * cell_h)))
        target[gy, gx, base + 4] = logit(0.95)
        box_mask[gy, gx, base : base + 4] = 1.0
    return target, obj_mask, box_mask


def robot_batch(n: int, rng: np.random.Generator):
    """(x, target, obj_mask, box_mask) arrays for n scenes."""
    xs = np.empty((n, 60, 80, 3), np.float32)
    ts = np.empty((n, GRID_H, GRID_W, N_ANCHORS * 5), np.float32)
    oms = np.empty_like(ts)
    bms = np.empty_like(ts)
    for i in range(n):
        img, boxes = robot_scene(rng)
        xs[i] = img
        ts[i], oms[i], bms[i] = robot_target(boxes)
    return xs, ts, oms, bms
