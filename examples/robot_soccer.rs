//! End-to-end driver (the repo's E2E validation): the paper's robot-soccer
//! scenario on a full serving stack.
//!
//! Per frame: render a synthetic soccer scene → scanline segmentation +
//! circle fitting extracts ball candidates (§III-A, ~20/frame in the
//! paper) → every 16×16 candidate patch is classified by the ball CNN
//! through the coordinator → detections assembled with NMS.
//!
//! Runs the same pipeline over three interchangeable engines (generated C,
//! naive interpreter, XLA/PJRT artifact) and reports per-frame latency —
//! the paper's central claim rendered as one table. With trained weights
//! in `models/` it also reports detection recall against ground truth.
//!
//! ```sh
//! make artifacts && cargo run --release --example robot_soccer
//! ```

use nncg::bench_harness::Table;
use nncg::codegen::CodegenOptions;
use nncg::coordinator;
use nncg::experiments::{build_engine, default_artifacts_dir, default_weights_dir, default_work_dir, load_model};
use nncg::runtime::EngineKind;
use nncg::tensor::Tensor;
use nncg::util::{fmt_us, XorShift64};
use nncg::vision::{ball, nms, render};

const FRAMES: usize = 40;

fn main() -> anyhow::Result<()> {
    let model = load_model("ball", &default_weights_dir())?;
    let trained = default_weights_dir().join("ball.nncgw").exists();
    println!(
        "ball classifier: {} params, weights: {}",
        model.num_params(),
        if trained { "trained (models/)" } else { "seeded random" }
    );

    let mut table = Table::new(
        &format!("robot_soccer: {FRAMES} frames end-to-end (extract + classify + NMS)"),
        &["engine", "frames/s", "candidates/frame", "extract µs/frame", "classify µs/frame", "recall"],
    );

    for kind in [EngineKind::Nncg, EngineKind::Interp, EngineKind::Xla] {
        let engine = match build_engine(kind, &model, &CodegenOptions::sse3(), &default_artifacts_dir(), &default_work_dir()) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("{}: unavailable ({e})", kind.name());
                continue;
            }
        };
        let handle = coordinator::serve_single("ball", engine, 1);

        let mut rng = XorShift64::new(4242);
        let (mut n_cand, mut extract_us, mut classify_us) = (0usize, 0.0f64, 0.0f64);
        let (mut gt_balls, mut hits) = (0usize, 0usize);
        let t_start = std::time::Instant::now();
        for _ in 0..FRAMES {
            let (img, truth) = render::soccer_frame(60, 80, 1 + rng.below(2), rng.below(3), &mut rng);
            let t0 = std::time::Instant::now();
            let cands = ball::extract_candidates(&img, &ball::BallExtractorConfig::default());
            extract_us += t0.elapsed().as_secs_f64() * 1e6;
            n_cand += cands.len();

            let patches: Vec<Tensor> = cands.iter().map(|c| ball::candidate_patch(&img, c)).collect();
            let t1 = std::time::Instant::now();
            let outs = if patches.is_empty() { vec![] } else { handle.infer_burst("ball", patches)? };
            classify_us += t1.elapsed().as_secs_f64() * 1e6;

            let dets: Vec<_> = cands
                .iter()
                .zip(&outs)
                .filter(|(_, o)| o.data()[1] > 0.5)
                .map(|(c, o)| ball::to_detection(c, o.data()[1]))
                .collect();
            let dets = nms(dets, 0.3);
            gt_balls += truth.balls.len();
            for gt in &truth.balls {
                if dets.iter().any(|d| d.iou(gt) > 0.25) {
                    hits += 1;
                }
            }
        }
        let wall = t_start.elapsed().as_secs_f64();
        handle.shutdown();

        table.row(vec![
            kind.name().to_string(),
            format!("{:.1}", FRAMES as f64 / wall),
            format!("{:.1}", n_cand as f64 / FRAMES as f64),
            fmt_us(extract_us / FRAMES as f64),
            fmt_us(classify_us / FRAMES as f64),
            if trained { format!("{:.0}%", 100.0 * hits as f64 / gt_balls.max(1) as f64) } else { "n/a (untrained)".into() },
        ]);
    }
    println!("{}", table.render());
    println!("(recall is only meaningful after `make train`; latency columns are the paper's story)");
    Ok(())
}
