//! Pedestrian monitoring scenario (paper §III-A, Daimler use case):
//! sliding-window scan over street frames, batched classification through
//! the coordinator, and a latency budget check against a 10 Hz camera.
//!
//! Demonstrates the [`Batcher`] policy trade-off the paper discusses: on
//! the CPU path, immediate dispatch beats waiting for batches.
//!
//! ```sh
//! cargo run --release --example pedestrian_monitor
//! ```

use nncg::codegen::CodegenOptions;
use nncg::coordinator::{Batcher, BatcherPolicy};
use nncg::experiments::{build_engine, default_artifacts_dir, default_weights_dir, default_work_dir, load_model};
use nncg::runtime::EngineKind;
use nncg::util::XorShift64;
use nncg::vision::{nms, pedestrian, render};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = load_model("pedestrian", &default_weights_dir())?;
    let engine = build_engine(
        EngineKind::Nncg,
        &model,
        &CodegenOptions::sse3(),
        &default_artifacts_dir(),
        &default_work_dir(),
    )?;

    // A synthetic 72x90 street frame with a pedestrian planted via the
    // patch generator (pasted into the scene).
    let mut rng = XorShift64::new(11);
    let mut frame = nncg::tensor::Tensor::zeros(&[72, 90, 1]);
    for v in frame.data_mut() {
        *v = 0.45 + 0.15 * rng.next_f32();
    }
    let ped = render::pedestrian_patch(true, &mut rng);
    for i in 0..36 {
        for j in 0..18 {
            *frame.at3_mut(20 + i, 40 + j, 0) = ped.at3(i, j, 0);
        }
    }

    let cfg = pedestrian::ScanConfig::default();
    let wins = pedestrian::windows(&frame, &cfg);
    println!("sliding-window scan: {} windows over a 72x90 frame", wins.len());

    for (label, policy) in [
        ("immediate (latency-first, CPU)", BatcherPolicy::immediate()),
        ("batch-16 / 2ms deadline", BatcherPolicy::batched(16, Duration::from_millis(2))),
    ] {
        let t0 = std::time::Instant::now();
        let mut scores = Vec::with_capacity(wins.len());
        let mut batcher: Batcher<usize> = Batcher::new(policy);
        let mut flush = |idxs: Vec<usize>, scores: &mut Vec<(usize, f32)>| -> anyhow::Result<()> {
            for idx in idxs {
                let patch = pedestrian::window_patch(&frame, wins[idx]);
                let out = engine.infer(&patch)?;
                scores.push((idx, out.data()[1]));
            }
            Ok(())
        };
        for idx in 0..wins.len() {
            if let Some(batch) = batcher.push(idx) {
                flush(batch, &mut scores)?;
            } else if batcher.deadline_due() {
                let b = batcher.flush();
                flush(b, &mut scores)?;
            }
        }
        flush(batcher.flush(), &mut scores)?;
        let us = t0.elapsed().as_secs_f64() * 1e6;

        scores.sort_by_key(|(i, _)| *i);
        let flat: Vec<f32> = scores.iter().map(|(_, s)| *s).collect();
        let dets = nms(pedestrian::detections_from_scores(&wins, &flat, &cfg), 0.3);
        let budget_10hz = 100_000.0;
        println!(
            "{label}: frame scan {:.1}ms ({:.1}us/window), {} detections, 10Hz budget {}",
            us / 1000.0,
            us / wins.len() as f64,
            dets.len(),
            if us < budget_10hz { "OK" } else { "EXCEEDED" }
        );
    }
    println!("(detections are only meaningful after `make train`)");
    Ok(())
}
