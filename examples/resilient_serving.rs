//! Resilient serving walkthrough: deadlines, load shedding, circuit-breaker
//! fallback to the interpreter, and a background heal — the failure
//! semantics the paper's time-critical vision loop (§I-A) needs once the
//! compile-at-runtime engine can be unhealthy.
//!
//! The demo injects a deterministic fault plan (the generated-C stand-in
//! fails for a while), watches the breaker open, serves bit-identical
//! answers from the interpreter fallback, then heals the primary and shows
//! traffic returning to it.
//!
//! ```sh
//! cargo run --release --example resilient_serving
//! ```

use nncg::coordinator::{
    serve_sharded, serve_with, BreakerConfig, FallbackEngine, Router, ServeConfig, ServeError,
    ShardConfig,
};
use nncg::faults::{FaultPlan, FaultSite, FaultSpec, FaultyEngine};
use nncg::graph::zoo;
use nncg::interp::InterpEngine;
use nncg::runtime::InferenceEngine;
use nncg::tensor::Tensor;
use nncg::util::XorShift64;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = zoo::ball_classifier().with_random_weights(7);

    // Primary: wrapped with a fault plan that fails the first 6 calls —
    // standing in for a generated-C engine whose object went bad.
    let healthy: Arc<dyn InferenceEngine> = Arc::new(InterpEngine::new(model.clone())?);
    let plan = FaultPlan::builder(42).site(FaultSite::EngineFail, FaultSpec::First(6)).build();
    let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(Arc::clone(&healthy), plan));

    // Fallback: a fresh interpreter over the same weights (bit-identical).
    let fallback: Arc<dyn InferenceEngine> = Arc::new(InterpEngine::new(model.clone())?);

    // Coordinator first (over an empty router) so the fallback wrapper can
    // share its metrics counters; then hot-register the wrapped engine.
    let router = Arc::new(Router::new());
    let handle = serve_with(
        Arc::clone(&router),
        ServeConfig {
            workers: 1,
            queue_capacity: 64,
            default_deadline: Some(Duration::from_millis(250)),
        },
    );
    let wrapped = Arc::new(
        FallbackEngine::new(
            primary,
            Arc::clone(&fallback),
            BreakerConfig { failure_threshold: 3, cooldown: Duration::from_millis(20) },
        )
        .with_counters(Arc::clone(handle.metrics.counters())),
    );
    router.register("ball", Arc::clone(&wrapped) as Arc<dyn InferenceEngine>);

    let mut rng = XorShift64::new(1);
    let x = Tensor::rand(&[16, 16, 1], 0.0, 1.0, &mut rng);
    let reference = fallback.infer(&x)?;

    println!("phase 1: primary failing — breaker opens, interpreter serves");
    for i in 0..6 {
        let y = handle.infer("ball", x.clone()).map_err(anyhow::Error::from)?;
        println!(
            "  req {i}: served, bit-identical to interpreter = {}, breaker = {:?}",
            y == reference,
            wrapped.breaker().state()
        );
    }

    println!("phase 2: background heal swaps a healthy primary in");
    let heal = wrapped.heal_in_background({
        let model = model.clone();
        move || Ok(Arc::new(InterpEngine::new(model)?) as Arc<dyn InferenceEngine>)
    });
    assert!(heal.join().expect("heal thread"), "heal must succeed");
    println!("  primary now: {}, breaker = {:?}", wrapped.primary_name(), wrapped.breaker().state());

    println!("phase 3: recovered — primary serves again");
    for i in 0..3 {
        let y = handle.infer("ball", x.clone()).map_err(anyhow::Error::from)?;
        println!("  req {i}: correct = {}", y == reference);
    }

    // Deadlines: an already-expired deadline is shed with a typed error
    // instead of computing a stale frame.
    match handle.infer_with_deadline("ball", x.clone(), Some(Duration::ZERO)) {
        Err(ServeError::DeadlineExceeded { late_by_us, .. }) => {
            println!("deadline demo: stale request shed ({late_by_us}µs late)");
        }
        other => println!("deadline demo: unexpected {other:?}"),
    }

    let snap = handle.stop();
    println!(
        "final counters: fallback-served={} breaker open/half-open/closed={}/{}/{} deadline-sheds={} errors={}",
        snap.fallback_served,
        snap.breaker_opens,
        snap.breaker_half_opens,
        snap.breaker_closes,
        snap.deadline_sheds,
        snap.errors
    );

    // ---- Sharded pool: the `nncg serve --shards 4 --steal on` shape ----
    //
    // Each shard owns its queue, batcher, supervisor, and breaker; a
    // model's traffic has a stable home shard, idle shards steal the
    // oldest half of a backlogged peer's queue (front-of-queue, so order
    // is preserved), and a shard can be drained and restarted under live
    // traffic without dropping an accepted request.
    println!("phase 4: sharded pool — stealing, live drain, per-shard counters");
    let router = Arc::new(Router::new());
    router.register(
        "ball",
        Arc::new(InterpEngine::new(model.clone())?) as Arc<dyn InferenceEngine>,
    );
    let sharded = serve_sharded(
        Arc::clone(&router),
        ShardConfig { shards: 4, steal: true, ..ShardConfig::default() },
    );
    let home = sharded.home_shard("ball");
    println!("  {} shards; \"ball\" homes on shard {home}", sharded.shards());

    // Burst traffic while recycling the home shard mid-stream: routing
    // steers around the draining shard and stealing keeps latency flat.
    let mut pending = Vec::new();
    for i in 0..200 {
        pending.push(sharded.submit("ball", x.clone(), None).map_err(anyhow::Error::from)?);
        if i == 40 {
            assert!(sharded.recycle_shard(home), "home shard must accept a recycle");
            println!("  recycled shard {home} under live traffic");
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().expect("exactly one reply per accepted request").is_ok() {
            ok += 1;
        }
    }
    println!("  burst: {ok}/200 served after a mid-stream shard restart");

    let snap = sharded.stop();
    println!(
        "  pool: steals={} ejects/probes/readmits={}/{}/{} drains={} stopped-replies={}",
        snap.steals,
        snap.shard_ejects,
        snap.shard_probes,
        snap.shard_readmits,
        snap.shard_drains,
        snap.stopped_replies
    );
    for s in &snap.shards {
        println!(
            "  shard {}: handled={} failed={} stolen-from={} stolen-by={} respawns={} drains={}",
            s.idx, s.handled, s.failed, s.stolen_from, s.stolen_by, s.respawns, s.drains
        );
    }
    if let Some(sick) = snap.sickest_shard() {
        println!("  sickest shard: {} (score {})", sick.idx, sick.sickness());
    } else {
        println!("  no sick shards");
    }
    Ok(())
}
