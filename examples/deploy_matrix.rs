//! Deployment matrix (paper §III-B "Generic Deployment").
//!
//! The paper's argument is that NNCG's output deploys where TF-XLA and
//! Glow cannot: any ANSI C compiler, 32-bit targets (the Nao's Atom Z530),
//! cross-`-march` builds (the Atom J1900). This example reproduces the
//! matrix on the host toolchain for all three paper models and reports
//! toolchain gates (e.g. missing multilib for `-m32`) honestly.
//!
//! ```sh
//! cargo run --release --example deploy_matrix
//! ```

use nncg::bench_harness::Table;
use nncg::cli::commands::deploy_matrix;

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Deployment matrix: can the generated C be built for each scenario?",
        &["model", "scenario", "result", "note"],
    );
    for model in ["ball", "pedestrian", "robot"] {
        for (scenario, ok, note) in deploy_matrix(model)? {
            table.row(vec![
                model.to_string(),
                scenario,
                if ok { "OK".into() } else { "gated".into() },
                if note.is_empty() { String::new() } else { format!("{:.60}", note) },
            ]);
        }
    }
    println!("{}", table.render());
    println!("paper comparison: TF XLA objects depend on Eigen (no 32-bit build);");
    println!("Glow emits host-AVX objects with no cross-target switch. NNCG's C");
    println!("compiles in every scenario the toolchain itself supports.");
    Ok(())
}
