//! Quickstart: the 60-second NNCG tour.
//!
//! 1. Build the paper's ball classifier (Table I).
//! 2. Generate its ANSI C, compile it, dlopen it.
//! 3. Classify a synthetic ball patch and time it against the naive
//!    interpreter.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nncg::bench_harness::{bench, BenchConfig};
use nncg::cc::CompiledCnn;
use nncg::codegen::{generate_c, CodegenOptions};
use nncg::graph::zoo;
use nncg::interp;
use nncg::util::XorShift64;
use nncg::vision::render;

fn main() -> anyhow::Result<()> {
    // A trained model would come from `nncg::model::load("models/ball")`;
    // random weights keep the example self-contained (latency is
    // weight-independent).
    let model = zoo::ball_classifier().with_random_weights(2020);
    println!("{}", model.describe());

    // The paper's artifact: one dependency-free C file.
    let opts = CodegenOptions::sse3_full_unroll();
    let c_src = generate_c(&model, &opts)?;
    println!(
        "generated {} lines of C ({} bytes), ISA/unroll = {}",
        c_src.lines().count(),
        c_src.len(),
        opts.tag()
    );

    // Compile + load + run.
    let work = std::env::temp_dir().join("nncg-quickstart");
    let cnn = CompiledCnn::build(&model, &opts, &work)?;
    let mut rng = XorShift64::new(7);
    let patch = render::ball_patch(true, &mut rng);
    let probs = cnn.infer(&patch)?;
    println!("P(no-ball, ball) = ({:.4}, {:.4})", probs.data()[0], probs.data()[1]);

    // Generated C vs interpreter: correctness + speed.
    let reference = interp::run(&model, &patch)?;
    println!("max |C - interp| = {:.2e}", probs.max_abs_diff(&reference)?);

    let cfg = BenchConfig::small();
    let mut out = vec![0.0f32; 2];
    let fast = bench(&cfg, || cnn.infer_into(patch.data(), &mut out));
    let slow = bench(&BenchConfig { iters: 500, ..cfg }, || {
        let _ = interp::run(&model, &patch).unwrap();
    });
    println!("generated C: {}", fast.summary());
    println!("interpreter: {}", slow.summary());
    println!("speed-up: {:.1}x", slow.median_us / fast.median_us);
    Ok(())
}
